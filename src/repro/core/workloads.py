"""Workload characterizations for the paper's six DNN models (§5.1, List 1)
and for the assigned architectures (traffic-demand view).

Each :class:`JobSpec` captures what the co-optimization needs: dense
(replicated) parameter bytes -> AllReduce demand; embedding tables / experts
-> MP demand; FLOPs -> compute time.

Multi-tenant clusters (§6 shared-cluster deployment): a :class:`JobSet`
holds several :class:`TenantJob`\\ s — a spec, a disjoint server placement,
and a fairness weight each — and aggregates their per-job demands into one
cluster-level :class:`~repro.core.demand.TrafficDemand` via
:meth:`JobSet.union`.  That union is what the shared TopologyFinder packs
into one physical degree budget.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

from .demand import (
    TrafficDemand,
    data_parallel_demand,
    dlrm_demand,
    moe_demand,
    remap_demand,
    union_demand,
)


@dataclass(frozen=True)
class JobSpec:
    name: str
    batch_per_gpu: int
    dense_params: float  # replicated parameter count
    flops_per_sample: float
    # Embedding-table models (DLRM/NCF): tables create MP broadcast+incast.
    n_tables: int = 0
    table_rows: float = 0.0
    table_dim: int = 0
    # MoE models: EP all-to-all.
    n_experts: int = 0
    top_k: int = 0
    moe_hidden: int = 0
    d_model: int = 0
    n_moe_layers: int = 0
    bytes_per_param: int = 4
    bytes_per_activation: int = 4

    @property
    def dense_bytes(self) -> float:
        return self.dense_params * self.bytes_per_param

    @property
    def state_bytes(self) -> float:
        """Bytes of model state a migration must checkpoint-restore: dense
        parameters plus embedding tables plus expert weights (the migration
        cost model, :func:`repro.core.costmodel.migration_cost`, owns any
        optimizer-state multiplier)."""
        params = (
            self.dense_params
            + self.n_tables * self.table_rows * self.table_dim
            + self.n_moe_layers * self.n_experts * 3 * self.d_model
            * self.moe_hidden
        )
        return params * self.bytes_per_param

    def with_batch(self, batch_per_gpu: int) -> "JobSpec":
        return replace(self, batch_per_gpu=batch_per_gpu)


# --- List 1 (§5.3 configurations) -----------------------------------------

VGG16 = JobSpec(
    name="vgg16", batch_per_gpu=64, dense_params=138e6, flops_per_sample=3 * 15.5e9
)
RESNET50 = JobSpec(
    name="resnet50", batch_per_gpu=128, dense_params=25.6e6, flops_per_sample=3 * 4.1e9
)
BERT = JobSpec(
    # 12 blocks, hidden 1024, seq 64, embed 512.
    name="bert", batch_per_gpu=16, dense_params=152e6,
    flops_per_sample=6 * 152e6 * 64,
)
CANDLE = JobSpec(
    # 8 dense layers of 16384 + 16 feature layers of 16384: ~ 5.4e9 params.
    name="candle", batch_per_gpu=256, dense_params=5.4e9,
    flops_per_sample=2 * 3 * 5.4e9,
)
DLRM = JobSpec(
    # 64 tables x 1e7 rows x 128 dims; 8 dense 2048 + 16 feat 4096.
    name="dlrm", batch_per_gpu=128,
    dense_params=8 * 2048**2 + 16 * 4096**2,
    flops_per_sample=2 * 3 * (8 * 2048**2 + 16 * 4096**2),
    n_tables=64, table_rows=1e7, table_dim=128,
)
DLRM_A2A = JobSpec(  # §5.4 worst-case: 128 large tables on 128 servers,
    # embedding dims boosted ("128x relative to state-of-the-art", §6) so
    # all-to-all reaches ~80% of AllReduce at batch 2048 as in Fig. 12.
    name="dlrm_a2a", batch_per_gpu=128,
    dense_params=8 * 2048**2 + 16 * 4096**2,
    flops_per_sample=2 * 3 * (8 * 2048**2 + 16 * 4096**2),
    n_tables=128, table_rows=1e7, table_dim=1024,
)
NCF = JobSpec(
    # 64 MF + 64 MLP tables of 1e6 users/items; dense 8 x 4096.
    name="ncf", batch_per_gpu=128, dense_params=8 * 4096**2,
    flops_per_sample=2 * 3 * 8 * 4096**2,
    n_tables=128, table_rows=1e6, table_dim=96,  # mean of MF 64 / MLP 128
)

MOE_16E = JobSpec(
    # Small mixture-of-experts transformer (shared-cluster churn traces):
    # 16 experts, top-2 routing, 8 MoE layers -> EP all-to-all demand.
    name="moe16", batch_per_gpu=32, dense_params=200e6,
    flops_per_sample=6 * 200e6 * 32,
    n_experts=16, top_k=2, moe_hidden=2048, d_model=1024, n_moe_layers=8,
)

PAPER_JOBS = {
    j.name: j for j in [VGG16, RESNET50, BERT, CANDLE, DLRM, DLRM_A2A, NCF]
}


# --- Multi-tenant JobSet (shared-cluster co-optimization) -------------------


@dataclass(frozen=True)
class TenantJob:
    """One resident job of a shared cluster: spec + placement + weight.

    ``servers`` maps the job's local node ids ``0..k-1`` to cluster nodes;
    placements of distinct tenants must be disjoint.  ``weight`` is the
    job's fairness weight (weighted max-min share and objective weight in
    the multi-job co-optimization)."""

    spec: JobSpec
    servers: tuple[int, ...]
    weight: float = 1.0
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "servers", tuple(int(s) for s in self.servers))
        if len(set(self.servers)) != len(self.servers):
            raise ValueError(f"tenant placement {self.servers!r} repeats a server")
        if self.weight <= 0:
            raise ValueError("tenant weight must be positive")

    @property
    def label(self) -> str:
        return self.name or self.spec.name

    @property
    def k(self) -> int:
        return len(self.servers)

    @property
    def flops_per_iteration(self) -> float:
        return self.spec.flops_per_sample * self.spec.batch_per_gpu * self.k


@dataclass
class JobSet:
    """The resident jobs of one shared cluster of ``n`` servers.

    Aggregates per-job (job-local) :class:`TrafficDemand`\\ s under each
    tenant's placement into one cluster-level union demand — the input the
    shared TopologyFinder packs into a single physical degree budget — and
    carries the per-job fairness weights every layer above consumes.
    """

    n: int
    tenants: list[TenantJob] = field(default_factory=list)

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        seen: set[int] = set()
        labels: set[str] = set()
        for t in self.tenants:
            if t.label in labels:
                raise ValueError(f"duplicate tenant label {t.label!r}")
            labels.add(t.label)
            s = set(t.servers)
            if s & seen:
                raise ValueError(
                    f"tenant {t.label!r} overlaps servers {sorted(s & seen)}"
                )
            if s and (min(s) < 0 or max(s) >= self.n):
                raise ValueError(
                    f"tenant {t.label!r} placed outside cluster of {self.n}"
                )
            seen |= s

    def tenant(self, label: str) -> TenantJob:
        for t in self.tenants:
            if t.label == label:
                return t
        raise KeyError(label)

    @property
    def labels(self) -> tuple[str, ...]:
        return tuple(t.label for t in self.tenants)

    def weights(self) -> dict[str, float]:
        return {t.label: t.weight for t in self.tenants}

    @property
    def total_weight(self) -> float:
        return float(sum(t.weight for t in self.tenants)) or 1.0

    def free_servers(self) -> set[int]:
        used = {s for t in self.tenants for s in t.servers}
        return set(range(self.n)) - used

    def restart_costs(self) -> dict[str, float]:
        """Per-tenant fault-restart pause (seconds): the checkpoint-restore
        reload of each tenant's model state
        (:func:`repro.core.costmodel.checkpoint_restart_s`).  Feed the
        result into :attr:`repro.core.simengine.Scenario.restart_s` so a
        fabric partition that stalls a tenant charges its real
        restore-from-checkpoint time when the partition heals."""
        from .costmodel import checkpoint_restart_s

        return {
            t.label: checkpoint_restart_s(t.spec.state_bytes)
            for t in self.tenants
        }

    def with_tenant(self, tenant: TenantJob) -> "JobSet":
        return JobSet(n=self.n, tenants=[*self.tenants, tenant])

    def without(self, label: str) -> "JobSet":
        kept = [t for t in self.tenants if t.label != label]
        if len(kept) == len(self.tenants):
            raise KeyError(label)
        return JobSet(n=self.n, tenants=kept)

    def with_placement(self, label: str, servers: Sequence[int]) -> "JobSet":
        """The same set with tenant ``label`` moved to ``servers`` (a
        candidate placement or an adopted migration); every other tenant is
        untouched.  Validation re-runs, so an overlapping move raises."""
        moved = [
            replace(t, servers=tuple(int(s) for s in servers))
            if t.label == label else t
            for t in self.tenants
        ]
        if all(t.label != label for t in self.tenants):
            raise KeyError(label)
        return JobSet(n=self.n, tenants=moved)

    def union(self, demands: Mapping[str, TrafficDemand]) -> TrafficDemand:
        """Cluster-level union of per-tenant job-local demands.

        ``demands[label]`` is tenant ``label``'s demand on ``tenant.k``
        local nodes; each is embedded under its placement and summed.  At
        or above the sparse threshold
        (:func:`~repro.core.demand.sparse_min_nodes`) the union is built
        straight from each tenant's COO entries
        (:func:`~repro.core.demand.union_embedded`, bit-identical) so no
        per-tenant (n, n) matrix is ever materialized."""
        from .demand import sparse_min_nodes, union_embedded

        if self.n >= sparse_min_nodes():
            return union_embedded(
                ((demands[t.label], t.servers) for t in self.tenants),
                self.n,
            )
        parts = [
            remap_demand(demands[t.label], t.servers, self.n)
            for t in self.tenants
        ]
        return union_demand(parts, n=self.n)

    def union_for(self, strategies: Mapping[str, object]) -> TrafficDemand:
        """Union demand under per-tenant strategies: ``strategies[label]``
        is any object with a ``demand(spec, n)`` method (a
        :class:`~repro.core.strategy_search.Strategy`)."""
        return self.union({
            t.label: strategies[t.label].demand(t.spec, t.k)
            for t in self.tenants
        })


def placement_diff(
    old: JobSet, new: JobSet
) -> dict[str, tuple[tuple[int, ...], tuple[int, ...]]]:
    """Tenants whose server set differs between two JobSets:
    ``{label: (old_servers, new_servers)}``.  Labels present in only one set
    (admissions, departures) are ignored — the diff prices *migrations*, and
    a migration needs both endpoints."""
    old_by = {t.label: t.servers for t in old.tenants}
    diff: dict[str, tuple[tuple[int, ...], tuple[int, ...]]] = {}
    for t in new.tenants:
        before = old_by.get(t.label)
        if before is not None and set(before) != set(t.servers):
            diff[t.label] = (before, t.servers)
    return diff


# --- Demand construction given a strategy ----------------------------------


def job_demand(
    job: JobSpec,
    n: int,
    table_hosts: Sequence[int] | None = None,
    ep_group_size: int = 0,
    schedule: str = "ring",
) -> TrafficDemand:
    """Translate (job, parallelization strategy) -> per-iteration demand.

    ``table_hosts`` None => pure data parallelism (embedding tables, if any,
    are replicated and join the AllReduce — the paper's Fig. 1a 44 GB case).
    ``schedule`` picks the collective schedule the AllReduce groups compile
    under (:mod:`repro.core.schedules`); ``"ring"`` is the byte-identical
    default (groups stay mutable ring demand).
    """
    if schedule != "ring":
        from .schedules import apply_schedule

        return apply_schedule(
            job_demand(job, n, table_hosts=table_hosts,
                       ep_group_size=ep_group_size),
            schedule,
        )
    if job.n_experts and ep_group_size > 1:
        # Clamp to the job's node count (a tenant's shard may be smaller
        # than the strategy's preferred EP group).
        ep_group_size = min(ep_group_size, n)
        groups = [
            tuple(range(g, min(g + ep_group_size, n)))
            for g in range(0, n, ep_group_size)
        ]
        # Tokens routed to top_k experts: dispatch + combine per MoE layer.
        tokens = job.batch_per_gpu
        a2a_bytes = (
            2 * job.n_moe_layers * tokens * job.top_k * job.d_model
            * job.bytes_per_activation / max(1, ep_group_size - 1)
        )
        expert_params = (
            job.n_moe_layers * job.n_experts * 3 * job.d_model * job.moe_hidden
            / max(1, n // ep_group_size)
        )
        return moe_demand(
            n, job.dense_bytes, groups, a2a_bytes,
            expert_param_bytes=expert_params * job.bytes_per_param,
        )

    if job.n_tables and table_hosts:
        table_hosts = tuple(sorted(set(table_hosts)))
        # Activations out per host per iteration: every other server's batch
        # worth of looked-up rows for the tables this host owns.
        tables_per_host = job.n_tables / len(table_hosts)
        act = (
            job.batch_per_gpu * job.table_dim * job.bytes_per_activation
            * tables_per_host
        )
        return dlrm_demand(n, job.dense_bytes, table_hosts, act)

    params = job.dense_params
    if job.n_tables:
        params = params + job.n_tables * job.table_rows * job.table_dim
    return data_parallel_demand(n, params * job.bytes_per_param)
