"""FlexNetPacket-style event-driven simulator (§5.1), at flow granularity.

Simulates a task graph of compute tasks and network flows over a fabric with
per-link capacities.  Flow rates follow progressive-filling max-min fairness,
recomputed at every arrival/finish event — the fluid limit of the paper's
htsim packet simulation, adequate for iteration-time and shared-cluster
studies while staying fast enough to sweep configurations.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

PROPAGATION_DELAY = 1e-6  # §5.1: link propagation delay 1 us


@dataclass
class Task:
    """A schedulable unit.  Either compute (duration) or comm (bytes+route)."""

    tid: int
    kind: str  # "compute" | "flow"
    duration: float = 0.0  # compute seconds
    nbytes: float = 0.0  # flow size
    route: tuple[int, ...] = ()  # node path for flows
    deps: tuple[int, ...] = ()


@dataclass
class _FlowState:
    task: Task
    remaining: float
    rate: float = 0.0


@dataclass
class SimResult:
    makespan: float
    finish_times: dict[int, float] = field(default_factory=dict)


class FlowSim:
    """Event-driven max-min fair flow simulator."""

    def __init__(self, link_bandwidth: dict[tuple[int, int], float]):
        self.link_bw = dict(link_bandwidth)

    def _max_min_rates(self, flows: list[_FlowState]) -> None:
        remaining_bw = dict(self.link_bw)
        unfrozen = [f for f in flows if f.task.route]
        for f in flows:
            f.rate = 0.0
        # Progressive filling.
        while unfrozen:
            # bottleneck link: min over links of (available / #flows crossing)
            link_users: dict[tuple[int, int], list[_FlowState]] = {}
            for f in unfrozen:
                for link in zip(f.task.route[:-1], f.task.route[1:]):
                    link_users.setdefault(link, []).append(f)
            if not link_users:
                break
            bottleneck, users = min(
                link_users.items(),
                key=lambda kv: remaining_bw.get(kv[0], float("inf")) / len(kv[1]),
            )
            fair = remaining_bw.get(bottleneck, float("inf")) / len(users)
            for f in users:
                f.rate += fair
                for link in zip(f.task.route[:-1], f.task.route[1:]):
                    remaining_bw[link] = remaining_bw.get(link, float("inf")) - fair
            frozen_ids = {id(f) for f in users}
            unfrozen = [f for f in unfrozen if id(f) not in frozen_ids]

    def run(self, tasks: list[Task], start_time: float = 0.0) -> SimResult:
        by_id = {t.tid: t for t in tasks}
        pending_deps = {t.tid: set(t.deps) for t in tasks}
        ready = [t for t in tasks if not t.deps]
        finish_times: dict[int, float] = {}
        active_flows: list[_FlowState] = []
        # (finish_time, tid) heap for compute tasks.
        compute_heap: list[tuple[float, int]] = []
        now = start_time

        def release(tid: int, t_done: float) -> list[Task]:
            finish_times[tid] = t_done
            out = []
            for t in tasks:
                if tid in pending_deps[t.tid]:
                    pending_deps[t.tid].discard(tid)
                    if not pending_deps[t.tid] and t.tid not in finish_times:
                        out.append(t)
            return out

        def admit(t: Task) -> None:
            if t.kind == "compute":
                heapq.heappush(compute_heap, (now + t.duration, t.tid))
            else:
                active_flows.append(
                    _FlowState(task=t, remaining=max(t.nbytes, 1e-9))
                )

        for t in ready:
            admit(t)

        while active_flows or compute_heap:
            self._max_min_rates(active_flows)
            # Next flow completion.
            t_flow = float("inf")
            next_flow = None
            for f in active_flows:
                if f.rate > 0:
                    eta = now + f.remaining / f.rate + PROPAGATION_DELAY * (
                        len(f.task.route) - 1
                    )
                else:
                    eta = float("inf")
                if eta < t_flow:
                    t_flow, next_flow = eta, f
            t_comp = compute_heap[0][0] if compute_heap else float("inf")

            if t_comp == float("inf") and t_flow == float("inf"):
                # Deadlock (disconnected route): finish flows instantly to
                # avoid hanging; callers treat this as a routing bug.
                for f in active_flows:
                    for nt in release(f.task.tid, now):
                        admit(nt)
                active_flows.clear()
                continue

            t_next = min(t_flow, t_comp)
            # Progress all flows to t_next.
            dt = t_next - now
            for f in active_flows:
                f.remaining = max(0.0, f.remaining - f.rate * dt)
            now = t_next

            newly: list[Task] = []
            if t_comp <= t_flow and compute_heap:
                _, tid = heapq.heappop(compute_heap)
                newly.extend(release(tid, now))
            else:
                active_flows.remove(next_flow)
                newly.extend(release(next_flow.task.tid, now))
            for t in newly:
                admit(t)

        return SimResult(makespan=now - start_time, finish_times=finish_times)


def links_of(topology_graph) -> dict[tuple[int, int], float]:
    """Aggregate parallel links of a MultiDiGraph into per-pair capacity
    multipliers (callers scale by per-link bandwidth)."""
    caps: dict[tuple[int, int], float] = {}
    for a, b in topology_graph.edges():
        caps[(a, b)] = caps.get((a, b), 0.0) + 1.0
    return caps
