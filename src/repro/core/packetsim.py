"""Deprecated shim — the event-driven flow simulator lives in
:mod:`repro.core.simengine` now.

``FlowSim`` remains importable with its original interface, but it is a
thin wrapper over :class:`repro.core.simengine.FlowSimVec`, the vectorized
rewrite (flows x links incidence arrays instead of per-flow dicts).  New
code should use :class:`repro.core.simengine.SimEngine` directly, which
also expresses the shared-cluster / failure / reconfiguration scenarios
this module never could.
"""

from __future__ import annotations

from .simengine import (  # noqa: F401  (re-exported for compatibility)
    PROPAGATION_DELAY,
    FlowSimVec,
    SimResult,
    Task,
)


class FlowSim(FlowSimVec):
    """Deprecated alias of :class:`repro.core.simengine.FlowSimVec`."""


def links_of(topology_graph) -> dict[tuple[int, int], float]:
    """Aggregate parallel links of a MultiDiGraph into per-pair capacity
    multipliers (callers scale by per-link bandwidth)."""
    caps: dict[tuple[int, int], float] = {}
    for a, b in topology_graph.edges():
        caps[(a, b)] = caps.get((a, b), 0.0) + 1.0
    return caps
