"""Deprecated shim — the event-driven flow simulator lives in
:mod:`repro.core.simengine` now.

``FlowSim`` remains importable with its original interface, but it is a
thin wrapper over :class:`repro.core.simengine.FlowSimVec`, the vectorized
rewrite (flows x links incidence arrays instead of per-flow dicts).  Every
name imported from *this* module emits a :class:`DeprecationWarning`; new
code should use :class:`repro.core.simengine.SimEngine` directly, which
also expresses the shared-cluster / failure / reconfiguration scenarios
this module never could.
"""

from __future__ import annotations

import warnings

from . import simengine as _simengine

_FlowSim = None


def _flow_sim_class():
    """Build the legacy ``FlowSim`` subclass lazily so plain module import
    stays warning-free."""
    global _FlowSim
    if _FlowSim is None:

        class FlowSim(_simengine.FlowSimVec):
            """Deprecated alias of :class:`repro.core.simengine.FlowSimVec`."""

        _FlowSim = FlowSim
    return _FlowSim


def _links_of(topology_graph) -> dict[tuple[int, int], float]:
    """Aggregate parallel links of a MultiDiGraph into per-pair capacity
    multipliers (callers scale by per-link bandwidth)."""
    caps: dict[tuple[int, int], float] = {}
    for a, b in topology_graph.edges():
        caps[(a, b)] = caps.get((a, b), 0.0) + 1.0
    return caps


_DEPRECATED_SHIMS = {
    "PROPAGATION_DELAY": lambda: _simengine.PROPAGATION_DELAY,
    "FlowSimVec": lambda: _simengine.FlowSimVec,
    "SimResult": lambda: _simengine.SimResult,
    "Task": lambda: _simengine.Task,
    "FlowSim": _flow_sim_class,
    "links_of": lambda: _links_of,
}


def __getattr__(name: str):
    shim = _DEPRECATED_SHIMS.get(name)
    if shim is not None:
        warnings.warn(
            f"repro.core.packetsim.{name} is deprecated; use "
            "repro.core.simengine (FlowSimVec / SimEngine) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return shim()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
