"""TotientPerms (paper Algorithm 2, §4.2) — ring-AllReduce permutations.

Theorem 2 (paper, App. E.1): for a cluster of ``n`` nodes, every integer
``p < n`` with ``gcd(p, n) == 1`` generates a unique *regular* ring
permutation ``S_i -> S_{(i+p) mod n}``.  These are exactly the generators of
the cyclic group Z_n^+, and their count is Euler's totient ``phi(n)`` —
hence the algorithm's name.  At large ``n`` the paper prunes the stride set
to the primes (plus 1), shrinking it to ``O(n / ln n)`` by the Prime Number
Theorem (:func:`prime_coprimes`).

Notation mapping (paper -> code): servers ``S_i`` -> group-local indices
``0..k-1``; a permutation ``p`` -> :class:`RingPermutation` (``.p`` is the
stride, ``.members`` maps local index -> cluster node id); the output set
``P`` of Algorithm 2 -> :class:`PermutationSet`.  The AllReduce group may be
a subset of the cluster (hybrid strategies replicate a layer over ``k`` of
``n`` servers); permutations are generated in the *group-local* index space
and mapped back onto the member node ids, so a stride's physical edges come
from :meth:`RingPermutation.edges`.

Downstream: :func:`repro.core.select_perms.select_permutations` (Alg. 3)
picks ``d_k`` of these strides per group; CoinChangeMod (Alg. 4) then routes
arbitrary pairs over the chosen rings.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence


def coprimes(n: int) -> list[int]:
    """All valid ring strides for a group of size ``n`` (Euler totient set)."""
    if n < 2:
        return []
    return [p for p in range(1, n) if math.gcd(p, n) == 1]


def prime_coprimes(n: int) -> list[int]:
    """Strides restricted to primes (plus 1) — the paper's large-scale
    reduction of the search space to O(n / ln n) via the Prime Number
    Theorem."""

    def is_prime(x: int) -> bool:
        if x < 2:
            return False
        for f in range(2, int(math.isqrt(x)) + 1):
            if x % f == 0:
                return False
        return True

    return [1] + [p for p in coprimes(n) if is_prime(p)]


def ring_order(n: int, p: int, start: int = 0) -> list[int]:
    """Visit order of the stride-``p`` ring over group-local ids 0..n-1."""
    if math.gcd(p, n) != 1:
        raise ValueError(f"stride p={p} is not coprime with n={n}: not a ring")
    return [(start + i * p) % n for i in range(n)]


def ring_edges(n: int, p: int) -> list[tuple[int, int]]:
    """Directed edges of the stride-``p`` ring: i -> (i+p) mod n."""
    order = ring_order(n, p)
    return [(order[i], order[(i + 1) % n]) for i in range(n)]


def is_valid_ring(n: int, edges: Sequence[tuple[int, int]]) -> bool:
    """A ring visits every node exactly once (Hamiltonian directed cycle)."""
    if len(edges) != n:
        return False
    nxt = {}
    for a, b in edges:
        if a in nxt:
            return False
        nxt[a] = b
    cur, seen = 0, set()
    for _ in range(n):
        if cur in seen or cur not in nxt:
            return False
        seen.add(cur)
        cur = nxt[cur]
    return cur == 0 and len(seen) == n


@dataclass(frozen=True)
class RingPermutation:
    """One stride-``p`` regular ring over an AllReduce group.

    ``members`` maps group-local index -> cluster node id.  ``edges()``
    returns cluster-level directed edges.
    """

    p: int
    members: tuple[int, ...]

    @property
    def size(self) -> int:
        return len(self.members)

    def order(self) -> list[int]:
        return [self.members[i] for i in ring_order(self.size, self.p)]

    def edges(self) -> list[tuple[int, int]]:
        return [(self.members[a], self.members[b]) for a, b in ring_edges(self.size, self.p)]


@dataclass
class PermutationSet:
    """Output of TotientPerms for one AllReduce group."""

    group: tuple[int, ...]
    perms: list[RingPermutation] = field(default_factory=list)

    @property
    def strides(self) -> list[int]:
        return [r.p for r in self.perms]


def totient_perms(members: Sequence[int], prime_only: bool | None = None) -> PermutationSet:
    """Algorithm 2.  Generate all regular ring permutations for an AllReduce
    group.

    Args:
      members: cluster node ids participating in this AllReduce group.
      prime_only: restrict strides to primes.  Defaults to automatic —
        full totient set for small groups, primes for k > 64 (the paper's
        large-scale mode).
    """
    members = tuple(members)
    k = len(members)
    if k < 2:
        return PermutationSet(group=members, perms=[])
    if prime_only is None:
        prime_only = k > 64
    strides = prime_coprimes(k) if prime_only else coprimes(k)
    perms = [RingPermutation(p=p, members=members) for p in strides]
    return PermutationSet(group=members, perms=perms)


def totient_perms_grouped(n: int, k: int, prime_only: bool | None = None) -> list[PermutationSet]:
    """Paper's Algorithm 2 signature: ``n`` total nodes partitioned into
    contiguous AllReduce groups of size ``k`` (n/k groups), each getting the
    same stride set.  Used when a layer is replicated across k-subsets."""
    if n % k != 0:
        raise ValueError(f"group size k={k} must divide n={n}")
    return [
        totient_perms(range(g * k, (g + 1) * k), prime_only=prime_only)
        for g in range(n // k)
    ]
