"""TopoOpt core: the paper's contribution.

- totient / select_perms: TotientPerms + SelectPermutations (Alg. 2/3)
- topology_finder: TopologyFinder (Alg. 1) + failure repair/degradation
- routing: CoinChangeMod (Alg. 4), k-shortest MP routes, bandwidth tax
- demand / workloads: traffic demand extraction per strategy
- strategy_search / alternating: MCMC + alternating optimization (Fig. 6),
  warm-startable from an incumbent plan for online re-optimization
- simengine: unified scenario-driven simulator (SimEngine facade; vectorized
  max-min-fair flows, shared clusters, failures, OCS reconfiguration epochs,
  observer hooks for mid-run plan mutation)
- online: ReoptPolicy/ReoptController/run_online — dynamic TopoOpt reacting
  to failures and load shifts, plus topology-aware job placement
  (candidate-placement co-search and churn-priced tenant migration)
- netsim / packetsim / fabrics / ocs_reconfig: FlexNet & FlexNetPacket
  analogues (netsim/packetsim/ocs_reconfig are shims behind simengine now)
- costmodel: §5.2 cost analysis
- collectives / device_order: JAX-native multi-ring AllReduce + mesh ordering
"""

from .alternating import (
    CoOptResult,
    JobSetPlan,
    alternating_optimize,
    co_optimize_jobset,
    initial_topology,
)
from .costmodel import migration_cost
from .demand import (
    AllReduceGroup,
    TrafficDemand,
    rebase_demand,
    remap_demand,
    union_demand,
)
from .netsim import HardwareSpec, _iteration_time as iteration_time, compute_time
from .online import (
    JobSetController,
    ReoptController,
    ReoptPolicy,
    TraceEvent,
    edge_churn,
    place_arrival,
    place_candidates,
    run_online,
    run_online_jobset,
)
from .planeval import JobSetEvaluator, LRUCache, PlanEvaluator, plan_evaluator
from .routing import bandwidth_tax, coin_change_mod, path_length_stats
from .select_perms import coin_change_diameter, select_permutations, theorem1_bound
from .simengine import (
    DeadlineFairness,
    FairnessPolicy,
    MigrationRecord,
    WeightedFairness,
)
from .strategy_search import (
    Strategy,
    mcmc_search,
    mcmc_search_jobset,
    tenant_comm_times,
)
from .topology_finder import Topology, remove_pair, repair_topology, topology_finder
from .totient import RingPermutation, coprimes, prime_coprimes, ring_edges, totient_perms
from .workloads import (
    PAPER_JOBS,
    JobSet,
    JobSpec,
    TenantJob,
    job_demand,
    placement_diff,
)

__all__ = [
    "AllReduceGroup",
    "CoOptResult",
    "DeadlineFairness",
    "FairnessPolicy",
    "HardwareSpec",
    "JobSet",
    "JobSetController",
    "JobSetEvaluator",
    "JobSetPlan",
    "JobSpec",
    "LRUCache",
    "MigrationRecord",
    "PlanEvaluator",
    "PAPER_JOBS",
    "ReoptController",
    "ReoptPolicy",
    "RingPermutation",
    "Strategy",
    "TenantJob",
    "Topology",
    "TraceEvent",
    "TrafficDemand",
    "WeightedFairness",
    "alternating_optimize",
    "bandwidth_tax",
    "co_optimize_jobset",
    "coin_change_diameter",
    "coin_change_mod",
    "compute_time",
    "coprimes",
    "edge_churn",
    "initial_topology",
    "iteration_time",
    "job_demand",
    "mcmc_search",
    "mcmc_search_jobset",
    "migration_cost",
    "path_length_stats",
    "place_arrival",
    "place_candidates",
    "placement_diff",
    "plan_evaluator",
    "prime_coprimes",
    "rebase_demand",
    "remap_demand",
    "remove_pair",
    "repair_topology",
    "ring_edges",
    "run_online",
    "run_online_jobset",
    "select_permutations",
    "tenant_comm_times",
    "theorem1_bound",
    "topology_finder",
    "totient_perms",
    "union_demand",
]
