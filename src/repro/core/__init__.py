"""TopoOpt core: the paper's contribution.

- totient / select_perms: TotientPerms + SelectPermutations (Alg. 2/3)
- topology_finder: TopologyFinder (Alg. 1) + failure repair/degradation
- routing: CoinChangeMod (Alg. 4), k-shortest MP routes, bandwidth tax
- demand / workloads: traffic demand extraction per strategy
- strategy_search / alternating: MCMC + alternating optimization (Fig. 6),
  warm-startable from an incumbent plan for online re-optimization
- simengine: unified scenario-driven simulator (SimEngine facade; vectorized
  max-min-fair flows, shared clusters, failures, OCS reconfiguration epochs,
  observer hooks for mid-run plan mutation)
- online: ReoptPolicy/ReoptController/run_online — dynamic TopoOpt reacting
  to failures and load shifts, plus topology-aware job placement
- netsim / packetsim / fabrics / ocs_reconfig: FlexNet & FlexNetPacket
  analogues (netsim/packetsim/ocs_reconfig are shims behind simengine now)
- costmodel: §5.2 cost analysis
- collectives / device_order: JAX-native multi-ring AllReduce + mesh ordering
"""

from .alternating import CoOptResult, alternating_optimize, initial_topology
from .demand import AllReduceGroup, TrafficDemand
from .netsim import HardwareSpec, compute_time, iteration_time
from .online import (
    ReoptController,
    ReoptPolicy,
    TraceEvent,
    place_arrival,
    run_online,
)
from .routing import bandwidth_tax, coin_change_mod, path_length_stats
from .select_perms import coin_change_diameter, select_permutations, theorem1_bound
from .strategy_search import Strategy, mcmc_search
from .topology_finder import Topology, remove_pair, repair_topology, topology_finder
from .totient import RingPermutation, coprimes, prime_coprimes, ring_edges, totient_perms
from .workloads import PAPER_JOBS, JobSpec, job_demand

__all__ = [
    "AllReduceGroup",
    "CoOptResult",
    "HardwareSpec",
    "JobSpec",
    "PAPER_JOBS",
    "ReoptController",
    "ReoptPolicy",
    "RingPermutation",
    "Strategy",
    "Topology",
    "TraceEvent",
    "TrafficDemand",
    "alternating_optimize",
    "bandwidth_tax",
    "coin_change_diameter",
    "coin_change_mod",
    "compute_time",
    "coprimes",
    "initial_topology",
    "iteration_time",
    "job_demand",
    "mcmc_search",
    "path_length_stats",
    "place_arrival",
    "prime_coprimes",
    "remove_pair",
    "repair_topology",
    "ring_edges",
    "run_online",
    "select_permutations",
    "theorem1_bound",
    "topology_finder",
    "totient_perms",
]
