"""JAX-native TotientPerms collectives (§6 "Modifications to NCCL").

The paper integrates TotientPerms into NCCL so parameter synchronization is
load-balanced across several ring-AllReduce permutations.  Here we implement
the same idea with :func:`jax.lax.ppermute` inside ``shard_map``:

* ``ring_all_reduce(x, axis, p)`` — bandwidth-optimal ring AllReduce over the
  stride-``p`` regular ring (reduce-scatter + all-gather, n-1 steps each).
* ``multi_ring_all_reduce(x, axis, strides)`` — split ``x`` into
  ``len(strides)`` chunks, each reduced around a *different* TotientPerms
  ring.  On a TPU torus each stride lands on a distinct ICI direction, so the
  chunks genuinely move in parallel — the degree-``d`` bandwidth of the paper.

All variants are bit-comparable to ``lax.psum`` (tests assert allclose; exact
for integer inputs).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size


def _mod_inverse(p: int, n: int) -> int:
    if math.gcd(p, n) != 1:
        raise ValueError(f"stride {p} not coprime with ring size {n}")
    return pow(p, -1, n)


def _ring_perm(n: int, p: int) -> list[tuple[int, int]]:
    """ppermute pairs: device i sends to (i + p) mod n."""
    return [(i, (i + p) % n) for i in range(n)]


def ring_all_reduce(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """Ring AllReduce over the stride-``p`` permutation of ``axis_name``.

    Must be called inside ``shard_map``.  Equivalent to ``lax.psum(x, axis)``.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    inv_p = _mod_inverse(p, n)
    perm = _ring_perm(n, p)
    # Position of this device along the ring: ring visits (j * p) % n.
    pos = (lax.axis_index(axis_name) * inv_p) % n

    shape = x.shape
    flat = x.reshape(-1)
    seg = -(-flat.size // n)  # ceil
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    acc = flat.reshape(n, seg)

    def seg_at(arr, idx):
        return lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    # Reduce-scatter: after n-1 steps, position j owns segment (j + 1) % n.
    for t in range(n - 1):
        send_idx = (pos - t) % n
        recv_idx = (pos - t - 1) % n
        sent = seg_at(acc, send_idx)
        received = lax.ppermute(sent, axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(
            acc, seg_at(acc, recv_idx) + received, recv_idx % n, axis=0
        )

    # All-gather the reduced segments back around the same ring.
    for t in range(n - 1):
        send_idx = (pos + 1 - t) % n
        recv_idx = (pos - t) % n
        sent = seg_at(acc, send_idx)
        received = lax.ppermute(sent, axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(acc, received, recv_idx % n, axis=0)

    out = acc.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def ring_reduce_scatter(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """Reduce-scatter over the stride-``p`` ring: input logically
    (n * chunk,) flattened; returns this device's reduced chunk, ordered so
    that ``ring_all_gather`` reassembles ``psum(x)``.  Device at ring position
    j returns segment (j+1) % n mapped back to device order."""
    n = axis_size(axis_name)
    if n == 1:
        return x.reshape(-1)
    inv_p = _mod_inverse(p, n)
    perm = _ring_perm(n, p)
    pos = (lax.axis_index(axis_name) * inv_p) % n

    flat = x.reshape(-1)
    seg = -(-flat.size // n)
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    acc = flat.reshape(n, seg)

    def seg_at(arr, idx):
        return lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    for t in range(n - 1):
        send_idx = (pos - t) % n
        recv_idx = (pos - t - 1) % n
        received = lax.ppermute(seg_at(acc, send_idx), axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(
            acc, seg_at(acc, recv_idx) + received, recv_idx % n, axis=0
        )
    # Owned segment index: (pos + 1) % n.
    return seg_at(acc, (pos + 1) % n)


def multi_ring_all_reduce(
    x: jax.Array, axis_name: str, strides: tuple[int, ...] | list[int]
) -> jax.Array:
    """AllReduce load-balanced over several TotientPerms rings (§6).

    ``x`` is split into ``len(strides)`` equal chunks; chunk r is reduced
    around the stride ``strides[r]`` ring.  All chunk reductions are
    independent programs, so XLA's latency-hiding scheduler can run them
    concurrently over distinct ICI links.
    """
    strides = tuple(strides)
    r = len(strides)
    if r == 0:
        raise ValueError("need at least one ring stride")
    if r == 1:
        return ring_all_reduce(x, axis_name, strides[0])

    shape = x.shape
    flat = x.reshape(-1)
    chunk = -(-flat.size // r)
    pad = chunk * r - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(r, chunk)

    reduced = [
        ring_all_reduce(chunks[i], axis_name, strides[i]) for i in range(r)
    ]
    out = jnp.concatenate(reduced).reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def topoopt_psum_fn(strides: tuple[int, ...] | None, axis_name: str):
    """The gradient-sync collective a training step should use: multi-ring
    TotientPerms AllReduce when a TopoOpt plan supplies strides, otherwise
    plain ``lax.psum`` (single XLA all-reduce)."""
    if strides:
        return partial(multi_ring_all_reduce, axis_name=axis_name, strides=tuple(strides))
    return partial(lax.psum, axis_name=axis_name)


def all_to_all_ring(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """All-to-all (MoE dispatch pattern) implemented as n-1 ppermute rotations
    around a stride-``p`` ring — the host-based-forwarding analogue for EP
    traffic on a direct-connect fabric.  ``x``: (n, ...) per-destination data;
    returns (n, ...) per-source data.  Equivalent to lax.all_to_all."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, me, 0, keepdims=False), me, axis=0
    )
    # Rotate the full payload around the ring; at each step keep the slice
    # destined to us.  Bandwidth-suboptimal vs switch all-to-all by the
    # average-hop factor — exactly the paper's bandwidth tax (§5.4).
    perm = _ring_perm(n, p)
    payload = x
    src = me
    for _ in range(n - 1):
        payload = lax.ppermute(payload, axis_name, perm)
        src = (src - p) % n
        mine = lax.dynamic_index_in_dim(payload, me, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, src, axis=0)
    return out
