"""JAX-native TotientPerms collectives (§6 "Modifications to NCCL").

The paper integrates TotientPerms into NCCL so parameter synchronization is
load-balanced across several ring-AllReduce permutations.  Here we implement
the same idea with :func:`jax.lax.ppermute` inside ``shard_map``:

* ``ring_all_reduce(x, axis, p)`` — bandwidth-optimal ring AllReduce over the
  stride-``p`` regular ring (reduce-scatter + all-gather, n-1 steps each).
* ``multi_ring_all_reduce(x, axis, strides)`` — split ``x`` into
  ``len(strides)`` chunks, each reduced around a *different* TotientPerms
  ring.  On a TPU torus each stride lands on a distinct ICI direction, so the
  chunks genuinely move in parallel — the degree-``d`` bandwidth of the paper.

All variants are bit-comparable to ``lax.psum`` (tests assert allclose; exact
for integer inputs).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size


def _mod_inverse(p: int, n: int) -> int:
    if math.gcd(p, n) != 1:
        raise ValueError(f"stride {p} not coprime with ring size {n}")
    return pow(p, -1, n)


def _ring_perm(n: int, p: int) -> list[tuple[int, int]]:
    """ppermute pairs: device i sends to (i + p) mod n."""
    return [(i, (i + p) % n) for i in range(n)]


def ring_all_reduce(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """Ring AllReduce over the stride-``p`` permutation of ``axis_name``.

    Must be called inside ``shard_map``.  Equivalent to ``lax.psum(x, axis)``.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    inv_p = _mod_inverse(p, n)
    perm = _ring_perm(n, p)
    # Position of this device along the ring: ring visits (j * p) % n.
    pos = (lax.axis_index(axis_name) * inv_p) % n

    shape = x.shape
    flat = x.reshape(-1)
    seg = -(-flat.size // n)  # ceil
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    acc = flat.reshape(n, seg)

    def seg_at(arr, idx):
        return lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    # Reduce-scatter: after n-1 steps, position j owns segment (j + 1) % n.
    for t in range(n - 1):
        send_idx = (pos - t) % n
        recv_idx = (pos - t - 1) % n
        sent = seg_at(acc, send_idx)
        received = lax.ppermute(sent, axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(
            acc, seg_at(acc, recv_idx) + received, recv_idx % n, axis=0
        )

    # All-gather the reduced segments back around the same ring.
    for t in range(n - 1):
        send_idx = (pos + 1 - t) % n
        recv_idx = (pos - t) % n
        sent = seg_at(acc, send_idx)
        received = lax.ppermute(sent, axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(acc, received, recv_idx % n, axis=0)

    out = acc.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def ring_reduce_scatter(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """Reduce-scatter over the stride-``p`` ring: input logically
    (n * chunk,) flattened; returns this device's reduced chunk, ordered so
    that ``ring_all_gather`` reassembles ``psum(x)``.  Device at ring position
    j returns segment (j+1) % n mapped back to device order."""
    n = axis_size(axis_name)
    if n == 1:
        return x.reshape(-1)
    inv_p = _mod_inverse(p, n)
    perm = _ring_perm(n, p)
    pos = (lax.axis_index(axis_name) * inv_p) % n

    flat = x.reshape(-1)
    seg = -(-flat.size // n)
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    acc = flat.reshape(n, seg)

    def seg_at(arr, idx):
        return lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    for t in range(n - 1):
        send_idx = (pos - t) % n
        recv_idx = (pos - t - 1) % n
        received = lax.ppermute(seg_at(acc, send_idx), axis_name, perm)
        acc = lax.dynamic_update_index_in_dim(
            acc, seg_at(acc, recv_idx) + received, recv_idx % n, axis=0
        )
    # Owned segment index: (pos + 1) % n.
    return seg_at(acc, (pos + 1) % n)


def multi_ring_all_reduce(
    x: jax.Array, axis_name: str, strides: tuple[int, ...] | list[int]
) -> jax.Array:
    """AllReduce load-balanced over several TotientPerms rings (§6).

    ``x`` is split into ``len(strides)`` equal chunks; chunk r is reduced
    around the stride ``strides[r]`` ring.  All chunk reductions are
    independent programs, so XLA's latency-hiding scheduler can run them
    concurrently over distinct ICI links.
    """
    strides = tuple(strides)
    r = len(strides)
    if r == 0:
        raise ValueError("need at least one ring stride")
    if r == 1:
        return ring_all_reduce(x, axis_name, strides[0])

    shape = x.shape
    flat = x.reshape(-1)
    chunk = -(-flat.size // r)
    pad = chunk * r - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(r, chunk)

    reduced = [
        ring_all_reduce(chunks[i], axis_name, strides[i]) for i in range(r)
    ]
    out = jnp.concatenate(reduced).reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def recursive_hd_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Recursive halving-doubling AllReduce (the latency-optimal schedule of
    :mod:`repro.core.schedules`): ``log2(n)`` halving exchanges
    (reduce-scatter with partner ``i XOR d``) followed by ``log2(n)``
    doubling exchanges (all-gather), ``2 log2(n)`` ppermute rounds total vs
    the ring's ``2 (n-1)``.  Power-of-two groups only — the demand compiler
    folds stragglers into the core, the runtime kernel keeps the strict
    form.  Equivalent to ``lax.psum(x, axis)`` (exact for integer inputs:
    every addition is a disjoint pairwise tree).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    if n < 2 or n & (n - 1):
        raise ValueError(
            f"recursive halving-doubling needs a power-of-two group, got {n}"
        )
    me = lax.axis_index(axis_name)

    shape = x.shape
    flat = x.reshape(-1)
    seg = -(-flat.size // n)  # ceil
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    acc = flat.reshape(n, seg)

    # Recursive halving: the live block [lo, lo + 2d) splits at each round;
    # the kept half accumulates the partner's complementary half.
    lo = jnp.zeros_like(me)
    d = n // 2
    while d >= 1:
        bit = (me >> (d.bit_length() - 1)) & 1
        keep_lo = lo + bit * d
        send_lo = lo + (1 - bit) * d
        perm = [(i, i ^ d) for i in range(n)]
        sent = lax.dynamic_slice_in_dim(acc, send_lo, d, axis=0)
        received = lax.ppermute(sent, axis_name, perm)
        kept = lax.dynamic_slice_in_dim(acc, keep_lo, d, axis=0)
        acc = lax.dynamic_update_slice_in_dim(
            acc, kept + received, keep_lo, axis=0
        )
        lo = keep_lo
        d //= 2
    # Device i now owns fully-reduced segment i (lo == me by construction).
    # Recursive doubling: exchange ever-larger aligned blocks back.
    d = 1
    while d < n:
        perm = [(i, i ^ d) for i in range(n)]
        sent = lax.dynamic_slice_in_dim(acc, lo, d, axis=0)
        received = lax.ppermute(sent, axis_name, perm)
        acc = lax.dynamic_update_slice_in_dim(acc, received, lo ^ d, axis=0)
        lo = jnp.minimum(lo, lo ^ d)
        d *= 2

    out = acc.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def _tree_all_reduce(x: jax.Array, axis_name: str, order: list[int]) -> jax.Array:
    """AllReduce over one balanced binary tree: heap node ``i`` (device
    ``order[i]``) parents ``order[(i-1)//2]``.  Reduce runs deepest level
    first (left/right children in separate ppermute rounds — a parent has
    one source per round), then the root's total broadcasts back down."""
    n = len(order)
    me = lax.axis_index(axis_name)
    # Heap indices grouped by depth: [1,2], [3..6], [7..14], ...
    levels: list[list[int]] = []
    start, width = 1, 2
    while start < n:
        levels.append(list(range(start, min(start + width, n))))
        start += width
        width *= 2
    acc = x
    for level in reversed(levels):
        for parity in (1, 0):  # left children first, then right
            pairs = [
                (order[i], order[(i - 1) // 2])
                for i in level
                if i % 2 == parity
            ]
            if not pairs:
                continue
            # Non-recipients get zeros from ppermute, so a plain add only
            # touches the parents.
            acc = acc + lax.ppermute(acc, axis_name, pairs)
    for level in levels:
        for parity in (1, 0):
            pairs = [
                (order[(i - 1) // 2], order[i])
                for i in level
                if i % 2 == parity
            ]
            if not pairs:
                continue
            received = lax.ppermute(acc, axis_name, pairs)
            mask = jnp.zeros((), dtype=bool)
            for _, dst in pairs:
                mask = mask | (me == dst)
            acc = jnp.where(mask, received, acc)
    return acc


def multi_tree_all_reduce(
    x: jax.Array, axis_name: str, strides: tuple[int, ...] | list[int]
) -> jax.Array:
    """AllReduce load-balanced over several balanced binary trees, one per
    TotientPerms ring order (the ``multi_tree`` schedule of
    :mod:`repro.core.schedules`): ``x`` splits into ``len(strides)`` chunks
    and chunk ``r`` reduces up / broadcasts down the tree laid over the
    stride ``strides[r]`` ring order.  ``2 floor(log2(n))`` serial rounds
    per tree; the trees are independent programs over (mostly) disjoint
    edges, so they overlap.  Equivalent to ``lax.psum`` (exact for integer
    inputs)."""
    strides = tuple(strides)
    r = len(strides)
    if r == 0:
        raise ValueError("need at least one tree stride")
    from .totient import ring_order

    n = axis_size(axis_name)
    if n == 1:
        return x
    orders = [[int(v) for v in ring_order(n, p)] for p in strides]

    shape = x.shape
    flat = x.reshape(-1)
    chunk = -(-flat.size // r)
    pad = chunk * r - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(r, chunk)

    reduced = [
        _tree_all_reduce(chunks[i], axis_name, orders[i]) for i in range(r)
    ]
    out = jnp.concatenate(reduced).reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def topoopt_psum_fn(
    strides: tuple[int, ...] | None,
    axis_name: str,
    schedule: str = "ring",
    group_size: int | None = None,
):
    """The gradient-sync collective a training step should use, selected from
    the searched :class:`~repro.core.strategy_search.Strategy` ``schedule``
    (all three kernels are ``lax.psum``-equivalent):

    * ``"ring"`` — multi-ring TotientPerms AllReduce when a TopoOpt plan
      supplies strides, otherwise plain ``lax.psum`` (single XLA all-reduce).
    * ``"recursive_hd"`` — recursive halving-doubling.  The strict runtime
      kernel needs a power-of-two group, so when ``group_size`` is known and
      is not one, selection falls back to the ring family — the same fold
      the demand compiler applies to straggler nodes.
    * ``"multi_tree"`` — balanced binary trees seeded from the TotientPerms
      ring orders; without strides there is no tree seed and plain
      ``lax.psum`` is used.
    """
    if schedule == "recursive_hd":
        if group_size is None or (group_size & (group_size - 1)) == 0:
            return partial(recursive_hd_all_reduce, axis_name=axis_name)
        schedule = "ring"  # straggler fold: non-pow2 groups keep ringing
    elif schedule == "multi_tree":
        if strides:
            return partial(
                multi_tree_all_reduce, axis_name=axis_name,
                strides=tuple(strides),
            )
        return partial(lax.psum, axis_name=axis_name)
    elif schedule != "ring":
        raise ValueError(
            f"unknown collective schedule {schedule!r}: "
            "expected 'ring', 'recursive_hd' or 'multi_tree'"
        )
    if strides:
        return partial(multi_ring_all_reduce, axis_name=axis_name, strides=tuple(strides))
    return partial(lax.psum, axis_name=axis_name)


def all_to_all_ring(x: jax.Array, axis_name: str, p: int = 1) -> jax.Array:
    """All-to-all (MoE dispatch pattern) implemented as n-1 ppermute rotations
    around a stride-``p`` ring — the host-based-forwarding analogue for EP
    traffic on a direct-connect fabric.  ``x``: (n, ...) per-destination data;
    returns (n, ...) per-source data.  Equivalent to lax.all_to_all."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, me, 0, keepdims=False), me, axis=0
    )
    # Rotate the full payload around the ring; at each step keep the slice
    # destined to us.  Bandwidth-suboptimal vs switch all-to-all by the
    # average-hop factor — exactly the paper's bandwidth tax (§5.4).
    perm = _ring_perm(n, p)
    payload = x
    src = me
    for _ in range(n - 1):
        payload = lax.ppermute(payload, axis_name, perm)
        src = (src - p) % n
        mine = lax.dynamic_index_in_dim(payload, me, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, src, axis=0)
    return out
