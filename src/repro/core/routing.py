"""Routing (Algorithm 4 + App. E.3): CoinChangeMod for AllReduce rings,
k-shortest-path for MP transfers, and host-based-forwarding accounting
(bandwidth tax, §5.4/§5.5).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import networkx as nx
import numpy as np


@dataclass
class Route:
    """A multi-hop path: node sequence src..dst (len >= 2)."""

    path: tuple[int, ...]

    @property
    def hops(self) -> int:
        return len(self.path) - 1


@dataclass
class RoutingTable:
    """Routes between node pairs.  Multiple routes per pair allowed
    (host-based forwarding load-balances across them)."""

    routes: dict[tuple[int, int], list[Route]] = field(default_factory=dict)

    def add(self, src: int, dst: int, path: tuple[int, ...]) -> None:
        self.routes.setdefault((src, dst), []).append(Route(path=path))

    def get(self, src: int, dst: int) -> list[Route]:
        return self.routes.get((src, dst), [])


def coin_change_mod(n: int, strides: list[int]) -> dict[int, list[int]]:
    """Algorithm 4.  For every node distance m in [1, n-1], find the minimal
    multiset of "coins" (selected ring strides) summing to m (mod n).

    Returns {m: [coin, coin, ...]} — the back-trace of coins; hopping
    coin-by-coin from src yields the route.  BFS over Z_n (uniform coin cost)
    is equivalent to the paper's DP and O(n * |coins|).
    """
    if n <= 1:
        return {}
    coins = sorted(set(strides))
    bt: dict[int, list[int]] = {0: []}
    frontier = [0]
    while frontier:
        nxt = []
        for v in frontier:
            for c in coins:
                w = (v + c) % n
                if w not in bt:
                    bt[w] = bt[v] + [c]
                    nxt.append(w)
        frontier = nxt
    bt.pop(0, None)
    return bt


def allreduce_routes(members: tuple[int, ...], strides: list[int]) -> RoutingTable:
    """Routes for every ordered pair of an AllReduce group over its stride
    rings (coin-change in group-local index space, App. E.3)."""
    n = len(members)
    table = RoutingTable()
    bt = coin_change_mod(n, strides)
    for i in range(n):
        for m, coin_seq in bt.items():
            j = (i + m) % n
            path = [i]
            for c in coin_seq:
                path.append((path[-1] + c) % n)
            table.add(members[i], members[j], tuple(members[v] for v in path))
    return table


def k_shortest_mp_routes(
    graph: nx.MultiDiGraph, mp: np.ndarray, k: int = 2
) -> RoutingTable:
    """k-shortest-path routing for MP transfers on the *combined* topology
    (Algorithm 1, line 20)."""
    table = RoutingTable()
    simple = nx.DiGraph(graph)  # collapse parallel links for path search
    srcs, dsts = np.nonzero(mp)
    for s, t in zip(srcs.tolist(), dsts.tolist()):
        if s == t:
            continue
        try:
            gen = nx.shortest_simple_paths(simple, s, t)
            best_len = None
            for idx, path in enumerate(gen):
                if best_len is None:
                    best_len = len(path)
                elif len(path) > best_len + 1:
                    break  # only near-shortest alternates
                table.add(s, t, tuple(path))
                if idx + 1 >= k:
                    # Stop before asking Yen's generator for the (k+1)-th
                    # path — it would compute (and discard) the most
                    # expensive spur sweep of the whole pair.
                    break
        except nx.NetworkXNoPath:
            continue
    return table


# ---------------------------------------------------------------------------
# Host-based forwarding accounting (§5.4, §5.5)
# ---------------------------------------------------------------------------


def _flow_triples(flows):
    """Iterate ``(src, dst, nbytes)`` from either a legacy list of tuples
    or the array-backed :class:`repro.core.netsim.Flows` — lazily, without
    materializing an intermediate tuple list."""
    src = getattr(flows, "src", None)
    if src is not None:
        return zip(src.tolist(), flows.dst.tolist(), flows.nbytes.tolist())
    return iter(flows)


def link_loads(
    graph: nx.MultiDiGraph,
    demand_flows,
    routing: RoutingTable,
) -> dict[tuple[int, int], float]:
    """Bytes carried by each directed link (parallel links between a pair
    share load evenly) when flows follow ``routing`` with equal splitting
    across the available routes of a pair.  ``demand_flows`` is a list of
    ``(src, dst, nbytes)`` tuples or a :class:`repro.core.netsim.Flows`."""
    loads: dict[tuple[int, int], float] = {}
    n_par: dict[tuple[int, int], int] = {}
    for u, v, _ in graph.edges(keys=True):
        n_par[(u, v)] = n_par.get((u, v), 0) + 1
        loads.setdefault((u, v), 0.0)
    for src, dst, nbytes in _flow_triples(demand_flows):
        routes = routing.get(src, dst)
        if not routes:
            continue
        share = nbytes / len(routes)
        for r in routes:
            for a, b in zip(r.path[:-1], r.path[1:]):
                loads[(a, b)] = loads.get((a, b), 0.0) + share
    return loads


def bandwidth_tax(demand_flows, routing: RoutingTable) -> float:
    """Ratio of bytes placed on the wire (including forwarded copies) to the
    logical demand (§5.4).  Fat-tree tax == 1 by definition.
    ``demand_flows`` is a list of tuples or a
    :class:`repro.core.netsim.Flows` (summed without tuple round-trips)."""
    if hasattr(demand_flows, "total"):
        logical = demand_flows.total
    else:
        logical = sum(b for _, _, b in demand_flows)
    if logical <= 0:
        return 1.0
    wire = 0.0
    for src, dst, nbytes in _flow_triples(demand_flows):
        routes = routing.get(src, dst)
        if not routes:
            wire += nbytes  # unroutable ~ direct (shouldn't happen on connected G)
            continue
        share = nbytes / len(routes)
        wire += sum(share * r.hops for r in routes)
    return wire / logical


def path_length_stats(routing: RoutingTable) -> dict[str, float]:
    """CDF-style stats over per-pair best path length (Fig. 14)."""
    lens = [min(r.hops for r in rs) for rs in routing.routes.values() if rs]
    if not lens:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    arr = np.asarray(lens, dtype=np.float64)
    return {
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
        "max": float(arr.max()),
    }
