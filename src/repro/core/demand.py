"""Traffic demand extraction (paper §2, §4.1 inputs).

A parallelization strategy + device placement induces two demand kinds:

* ``AllReduceGroup`` — type (2) dependencies: weight sync among the nodes
  replicating the same part of the model.  *Mutable*: any ring permutation of
  the group carries it equally well.
* ``T_MP`` — type (1) dependencies: activations/gradients between nodes
  holding different parts of the model (TP collectives, EP all-to-all, DLRM
  embedding broadcast/incast, PP stage edges).  *Immutable* node pairs.

Units: bytes per training iteration.

Multi-tenant clusters (§6 shared-cluster deployment) aggregate several
jobs' demands on one fabric: :func:`remap_demand` embeds a job-local demand
into cluster index space under a placement, and :func:`union_demand` sums
the embedded demands into one cluster-level :class:`TrafficDemand` (the
union the shared TopologyFinder packs).  The :class:`repro.core.workloads.JobSet`
abstraction drives both.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np


def sparse_min_nodes() -> int:
    """Node-count threshold for the sparse (COO) demand paths.

    ``REPRO_SPARSE_MIN_NODES`` (default 0: always sparse).  The sparse
    paths are bit-identical to the dense ones — this knob exists so fleet
    runs and ``benchmarks/bench_fleet.py`` can pin either path (e.g. a
    huge value forces the dense baseline) without code edits.
    """
    return int(os.environ.get("REPRO_SPARSE_MIN_NODES", "0"))


@dataclass(frozen=True)
class AllReduceGroup:
    """One AllReduce over ``members`` moving ``nbytes`` per member per
    iteration (ring AllReduce moves ~2 * nbytes per link around the ring)."""

    members: tuple[int, ...]
    nbytes: float

    @property
    def total(self) -> float:
        return self.nbytes * len(self.members)


@dataclass
class TrafficDemand:
    """Full per-iteration demand of a job on ``n`` nodes."""

    n: int
    allreduce: list[AllReduceGroup] = field(default_factory=list)
    mp: np.ndarray | None = None  # (n, n) bytes, mp[i, j] = i -> j
    # Serial latency rounds pinned by compiled collective schedules
    # (repro.core.schedules); uncompiled ring groups contribute their
    # 2 (k-1) rounds through demand_steps() instead.
    steps: float = 0.0

    def __post_init__(self):
        if self.mp is None:
            self.mp = np.zeros((self.n, self.n), dtype=np.float64)
        self.mp = np.asarray(self.mp, dtype=np.float64)
        assert self.mp.shape == (self.n, self.n)
        self.steps = float(self.steps)

    @property
    def sum_allreduce(self) -> float:
        return float(sum(g.total for g in self.allreduce))

    @property
    def sum_mp(self) -> float:
        return float(self.mp.sum())

    def mp_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(srcs, dsts, vals)`` of the nonzero MP entries in
        ``np.nonzero`` (row-major) order, cached on the demand.

        This is the sparse handle the fleet-scale pricing paths key on: a
        compiled evaluator prices a cached demand in O(active pairs)
        instead of re-scanning the (n, n) matrix.  The first call
        **freezes** ``mp`` against further in-place writes (demands are
        built first, priced after — a later write raises loudly instead of
        silently diverging from the cache); replacing the ``mp`` attribute
        wholesale invalidates the cache instead.
        """
        cached = getattr(self, "_coo", None)
        if cached is not None and cached[0] is self.mp:
            return cached[1]
        srcs, dsts = np.nonzero(self.mp)
        coo = (srcs, dsts, self.mp[srcs, dsts])
        self.mp.flags.writeable = False
        self._coo = (self.mp, coo)
        return coo

    def set_mp_coo(
        self, srcs: np.ndarray, dsts: np.ndarray, vals: np.ndarray
    ) -> None:
        """Attach a precomputed COO (caller's contract: unique pairs in
        row-major order whose values equal ``mp``'s bit-for-bit — e.g.
        built by :func:`remap_demand` / :func:`union_embedded` from parts
        whose COOs are known).  Freezes ``mp`` like :meth:`mp_coo`."""
        self.mp.flags.writeable = False
        self._coo = (self.mp, (srcs, dsts, vals))

    def add_mp(self, src: int, dst: int, nbytes: float) -> None:
        if src != dst:
            self.mp[src, dst] += nbytes

    def add_all_to_all(self, members: Sequence[int], nbytes_per_pair: float) -> None:
        members = list(members)
        if len(set(members)) != len(members):
            # Repeated members accumulate per occurrence; keep loop semantics.
            for i in members:
                for j in members:
                    if i != j:
                        self.mp[i, j] += nbytes_per_pair
            return
        idx = np.asarray(members, dtype=np.int64)
        if idx.size <= 1:
            return
        # One addition per off-diagonal cell — same arithmetic as the loop.
        block = self.mp[np.ix_(idx, idx)]
        diag = block.diagonal().copy()
        block += nbytes_per_pair
        np.fill_diagonal(block, diag)
        self.mp[np.ix_(idx, idx)] = block

    def add_broadcast(self, src: int, dsts: Iterable[int], nbytes: float) -> None:
        """One-to-many MP pattern (e.g. DLRM embedding activations out)."""
        targets = [j for j in dsts if j != src]
        if len(set(targets)) == len(targets):
            self.mp[src, targets] += nbytes  # one add per cell, as the loop
        else:
            for j in targets:
                self.mp[src, j] += nbytes

    def add_incast(self, srcs: Iterable[int], dst: int, nbytes: float) -> None:
        """Many-to-one MP pattern (e.g. DLRM embedding gradients back)."""
        sources = [i for i in srcs if i != dst]
        if len(set(sources)) == len(sources):
            self.mp[sources, dst] += nbytes
        else:
            for i in sources:
                self.mp[i, dst] += nbytes


# ---------------------------------------------------------------------------
# Multi-tenant aggregation: placement remap + union demand
# ---------------------------------------------------------------------------


def remap_demand(
    demand: TrafficDemand, servers: Sequence[int], n_cluster: int
) -> TrafficDemand:
    """Embed a job-local demand into cluster index space.

    ``servers[i]`` is the cluster node hosting the job's local node ``i``;
    AllReduce group members are relabelled and the MP matrix lands on the
    ``servers x servers`` block.  Mutability is preserved: the relabelled
    groups stay ring-permutable, the relabelled MP pairs stay pinned.
    """
    servers = tuple(int(s) for s in servers)
    if len(servers) != demand.n:
        raise ValueError(
            f"placement has {len(servers)} servers for a demand on {demand.n}"
        )
    if len(set(servers)) != len(servers):
        raise ValueError(f"placement {servers!r} repeats a server")
    if servers and not (0 <= min(servers) and max(servers) < n_cluster):
        raise ValueError(f"placement {servers!r} outside cluster of {n_cluster}")
    out = TrafficDemand(n=n_cluster, steps=demand.steps)
    for g in demand.allreduce:
        out.allreduce.append(
            AllReduceGroup(
                members=tuple(servers[m] for m in g.members), nbytes=g.nbytes
            )
        )
    if servers:
        idx = np.asarray(servers, dtype=np.int64)
        out.mp[np.ix_(idx, idx)] += demand.mp
        if n_cluster >= sparse_min_nodes():
            # The embedded matrix's nonzeros are exactly the job-local
            # nonzeros moved to (servers[s], servers[d]) — attach the COO
            # now (O(k^2) local scan) so pricing the cluster-level demand
            # never re-scans the (n, n) matrix.
            ls, ld, v = demand.mp_coo()
            gs, gd = idx[ls], idx[ld]
            order = np.lexsort((gd, gs))  # row-major global order
            out.set_mp_coo(gs[order], gd[order], v[order])
    return out


def rebase_demand(
    demand: TrafficDemand,
    old_servers: Sequence[int],
    new_servers: Sequence[int],
) -> TrafficDemand:
    """Relabel a *cluster-level* demand from one placement to another.

    ``old_servers[i]`` -> ``new_servers[i]``: AllReduce members are mapped
    through the placement bijection and the MP block moves from the old
    server set to the new one.  This is the candidate-placement /
    migration fast path: a tenant's embedded demand can be re-seated
    without rebuilding the whole union —
    ``rebase_demand(remap_demand(d, old, n), old, new)`` equals
    ``remap_demand(d, new, n)`` entry for entry.
    """
    old_servers = tuple(int(s) for s in old_servers)
    new_servers = tuple(int(s) for s in new_servers)
    if len(old_servers) != len(new_servers):
        raise ValueError(
            f"placement sizes differ: {len(old_servers)} vs {len(new_servers)}"
        )
    if len(set(new_servers)) != len(new_servers):
        raise ValueError(f"placement {new_servers!r} repeats a server")
    n = demand.n
    if new_servers and not (0 <= min(new_servers) and max(new_servers) < n):
        raise ValueError(f"placement {new_servers!r} outside cluster of {n}")
    mapping = dict(zip(old_servers, new_servers))
    out = TrafficDemand(n=n, steps=demand.steps)
    for g in demand.allreduce:
        out.allreduce.append(
            AllReduceGroup(
                members=tuple(mapping.get(m, m) for m in g.members),
                nbytes=g.nbytes,
            )
        )
    if old_servers:
        old_idx = np.asarray(old_servers, dtype=np.int64)
        new_idx = np.asarray(new_servers, dtype=np.int64)
        block = demand.mp[np.ix_(old_idx, old_idx)].copy()
        out.mp[:] = demand.mp
        out.mp[np.ix_(old_idx, old_idx)] = 0.0
        out.mp[np.ix_(new_idx, new_idx)] += block
    else:
        out.mp[:] = demand.mp
    return out


def union_demand(
    parts: Iterable[TrafficDemand], n: int | None = None
) -> TrafficDemand:
    """Sum cluster-level demands into one (MP matrices add; AllReduce groups
    concatenate, merging groups with identical member tuples).

    The union preserves totals exactly: ``sum_mp`` and ``sum_allreduce`` of
    the result equal the sums over ``parts`` — the invariant
    ``tests/test_multitenant.py`` pins.
    """
    parts = list(parts)
    if n is None:
        if not parts:
            raise ValueError("union_demand needs parts or an explicit n")
        n = parts[0].n
    out = TrafficDemand(n=n)
    sparse = n >= sparse_min_nodes()
    touched: list[np.ndarray] = []
    merged: dict[tuple[int, ...], float] = {}
    order: list[tuple[int, ...]] = []
    for p in parts:
        if p.n != n:
            raise ValueError(f"demand on {p.n} nodes in a union over {n}")
        if sparse:
            # Scatter only the part's nonzeros: each touched cell receives
            # the same addition, in the same part order, as the dense
            # ``out.mp += p.mp`` — and adding 0.0 to a nonnegative float is
            # a bitwise no-op, so skipping the zero cells is exact.
            ps, pd, pv = p.mp_coo()
            if ps.size:
                out.mp[ps, pd] += pv
                touched.append(ps.astype(np.int64) * n + pd)
        else:
            out.mp += p.mp
        out.steps = max(out.steps, p.steps)
        for g in p.allreduce:
            if g.members not in merged:
                order.append(g.members)
                merged[g.members] = 0.0
            merged[g.members] += g.nbytes
    out.allreduce = [
        AllReduceGroup(members=m, nbytes=merged[m]) for m in order
    ]
    if sparse:
        keys = (
            np.unique(np.concatenate(touched))
            if touched
            else np.zeros(0, dtype=np.int64)
        )
        srcs, dsts = keys // n, keys % n
        out.set_mp_coo(srcs, dsts, out.mp[srcs, dsts])
    return out


def union_embedded(
    parts: Iterable[tuple[TrafficDemand, Sequence[int]]], n: int
) -> TrafficDemand:
    """Union of job-local demands embedded under their placements.

    Bit-identical to ``union_demand([remap_demand(d, s, n) for d, s in
    parts], n)`` without materializing any per-tenant (n, n) matrix: each
    part contributes its COO entries straight into the one union matrix —
    O(active pairs) per tenant instead of O(n^2) — which is what lets
    fleet-sized jobsets re-union on every arrival/departure/move.  The
    per-cell additions are the dense path's exactly (same values, same
    part order; the dense path's additions of 0.0 elsewhere are bitwise
    no-ops on the nonnegative byte matrices).
    """
    out = TrafficDemand(n=n)
    touched: list[np.ndarray] = []
    merged: dict[tuple[int, ...], float] = {}
    order: list[tuple[int, ...]] = []
    for demand, servers in parts:
        servers = tuple(int(s) for s in servers)
        # Same placement validation as remap_demand.
        if len(servers) != demand.n:
            raise ValueError(
                f"placement has {len(servers)} servers for a demand on "
                f"{demand.n}"
            )
        if len(set(servers)) != len(servers):
            raise ValueError(f"placement {servers!r} repeats a server")
        if servers and not (0 <= min(servers) and max(servers) < n):
            raise ValueError(f"placement {servers!r} outside cluster of {n}")
        out.steps = max(out.steps, demand.steps)
        for g in demand.allreduce:
            members = tuple(servers[m] for m in g.members)
            if members not in merged:
                order.append(members)
                merged[members] = 0.0
            merged[members] += g.nbytes
        if servers:
            idx = np.asarray(servers, dtype=np.int64)
            ls, ld, v = demand.mp_coo()
            if ls.size:
                gs, gd = idx[ls], idx[ld]
                out.mp[gs, gd] += v
                touched.append(gs * n + gd)
    out.allreduce = [
        AllReduceGroup(members=m, nbytes=merged[m]) for m in order
    ]
    keys = (
        np.unique(np.concatenate(touched))
        if touched
        else np.zeros(0, dtype=np.int64)
    )
    srcs, dsts = keys // n, keys % n
    out.set_mp_coo(srcs, dsts, out.mp[srcs, dsts])
    return out


def demand_steps(demand: TrafficDemand) -> float:
    """Serial latency rounds of a demand — the α multiplier of the (α, β)
    cost model: the compiled-schedule ``demand.steps`` floor, raised to each
    active (nbytes > 0, k > 1) uncompiled ring group's ``2 (k-1)`` rounds.
    Topology-independent, so evaluators can memoize it per demand."""
    steps = demand.steps
    for g in demand.allreduce:
        k = len(g.members)
        if g.nbytes > 0.0 and k > 1:
            steps = max(steps, 2.0 * (k - 1))
    return steps


# ---------------------------------------------------------------------------
# Demand builders for the model families used in the paper + assigned archs.
# ---------------------------------------------------------------------------


def data_parallel_demand(n: int, param_bytes: float) -> TrafficDemand:
    """Pure DP: one global AllReduce of the full gradient per iteration."""
    d = TrafficDemand(n=n)
    d.allreduce.append(AllReduceGroup(members=tuple(range(n)), nbytes=param_bytes))
    return d


def hybrid_demand(
    n: int,
    dp_param_bytes: float,
    mp_pairs: Iterable[tuple[int, int, float]] = (),
    subgroup_allreduce: Iterable[tuple[Sequence[int], float]] = (),
) -> TrafficDemand:
    """Hybrid data+model parallel demand: a global (or per-subgroup)
    AllReduce for replicated parts plus explicit MP transfers."""
    d = TrafficDemand(n=n)
    if dp_param_bytes > 0:
        d.allreduce.append(AllReduceGroup(members=tuple(range(n)), nbytes=dp_param_bytes))
    for members, nbytes in subgroup_allreduce:
        d.allreduce.append(AllReduceGroup(members=tuple(members), nbytes=nbytes))
    for src, dst, nbytes in mp_pairs:
        d.add_mp(src, dst, nbytes)
    return d


def dlrm_demand(
    n: int,
    dense_param_bytes: float,
    table_hosts: Sequence[int],
    activation_bytes_per_host: float,
) -> TrafficDemand:
    """DLRM (§2.1): dense part replicated (AllReduce), embedding tables on
    ``table_hosts`` with one-to-many broadcast of looked-up rows and
    many-to-one incast of their gradients."""
    d = data_parallel_demand(n, dense_param_bytes)
    hosts = list(table_hosts)
    if len(set(hosts)) == len(hosts):
        # Vectorized build (the strategy-search hot path): every touched
        # cell starts at zero, so one row add + one column add + a diagonal
        # reset reproduces the per-host loop's values exactly.
        idx = np.asarray(hosts, dtype=np.int64)
        if idx.size:
            d.mp[idx, :] += activation_bytes_per_host
            d.mp[:, idx] += activation_bytes_per_host
            d.mp[idx, idx] = 0.0
        return d
    everyone = range(n)
    for h in hosts:
        d.add_broadcast(h, everyone, activation_bytes_per_host)
        d.add_incast(everyone, h, activation_bytes_per_host)
    return d


def moe_demand(
    n: int,
    dp_param_bytes: float,
    ep_groups: Iterable[Sequence[int]],
    a2a_bytes_per_pair: float,
    expert_param_bytes: float = 0.0,
) -> TrafficDemand:
    """MoE: dense grads AllReduce over everyone; expert grads AllReduce within
    each expert-replication group; token dispatch/combine all-to-all within
    each EP group (twice per layer pass, folded into a2a_bytes_per_pair)."""
    d = data_parallel_demand(n, dp_param_bytes)
    for g in ep_groups:
        d.add_all_to_all(g, a2a_bytes_per_pair)
        if expert_param_bytes > 0:
            d.allreduce.append(AllReduceGroup(members=tuple(g), nbytes=expert_param_bytes))
    return d
