"""Shared-cluster scheduler with look-ahead pre-provisioning (Appendix C).

A TopoOpt cluster is shardable: each job gets a disjoint set of servers and
a dedicated optical topology.  Patch panels reconfigure in minutes, so each
server interface is split Active/Look-ahead by a 1x2 mechanical switch: while
the Active plane runs current jobs, the Look-ahead plane pre-provisions the
*next* job's topology; when its servers free up, a microsecond 1x2 flip
activates it (no reconfiguration stall on the critical path).

This module simulates that policy: job arrivals -> server allocation ->
(pre-provision on look-ahead) -> flip at start -> release at completion,
charging the patch-panel latency only when a job starts before its
pre-provisioning finished.

Server selection is pluggable (``placement=``): lowest-id first fit (the
seed behaviour), best-fit ``"contiguous"`` blocks (fragmentation-resistant —
TotientPerms groups of contiguous ids map to physically adjacent patch-panel
ports), or any callable ``(free, k) -> servers`` — e.g. a closure over
:func:`repro.core.online.place_arrival` for live-fabric-aware placement on a
degraded cluster.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Sequence

PATCH_PANEL_RECONFIG_S = 120.0  # minutes-scale robotic reconfiguration
FLIP_S = 1e-6  # 1x2 mechanical switch flip


@dataclass(frozen=True)
class JobRequest:
    jid: int
    arrival_s: float
    n_servers: int
    duration_s: float  # training time once started


@dataclass
class JobRecord:
    req: JobRequest
    servers: tuple[int, ...] = ()
    provision_ready_s: float = 0.0
    start_s: float = 0.0
    end_s: float = 0.0

    @property
    def queueing_s(self) -> float:
        return self.start_s - self.req.arrival_s


@dataclass
class ClusterState:
    n_servers: int
    free: set = field(default_factory=set)

    def __post_init__(self):
        if not self.free:
            self.free = set(range(self.n_servers))


def first_fit(free: set, k: int) -> tuple[int, ...]:
    """Lowest-id servers (the seed policy)."""
    return tuple(sorted(free))[:k]


def contiguous_fit(free: set, k: int) -> tuple[int, ...]:
    """Best-fit contiguous block of server ids.

    Prefers the *smallest* free run that fits (classic best-fit, leaves big
    runs intact for big jobs); when no single run fits, gathers from the
    largest runs first to minimize the number of fragments the job spans.
    """
    ids = sorted(free)
    runs: list[tuple[int, int]] = []  # (length, start)
    start = prev = None
    for v in ids:
        if prev is None or v != prev + 1:
            if start is not None:
                runs.append((prev - start + 1, start))
            start = v
        prev = v
    if start is not None:
        runs.append((prev - start + 1, start))
    fitting = [r for r in runs if r[0] >= k]
    if fitting:
        _, s = min(fitting)
        return tuple(range(s, s + k))
    out: list[int] = []
    for length, s in sorted(runs, key=lambda r: (-r[0], r[1])):
        take = min(k - len(out), length)
        out.extend(range(s, s + take))
        if len(out) == k:
            break
    return tuple(sorted(out))


_PLACEMENTS = {"first_fit": first_fit, "contiguous": contiguous_fit}


def simulate(
    n_servers: int,
    jobs: list[JobRequest],
    lookahead: bool = True,
    reconfig_s: float = PATCH_PANEL_RECONFIG_S,
    placement: str | Callable[[set, int], Sequence[int]] = "first_fit",
) -> list[JobRecord]:
    """Event-driven shard scheduler.

    With ``lookahead`` the next queued job's topology is provisioned on the
    spare plane as soon as its servers are *identifiable* (enough free or
    soon-to-free servers), so its start pays only the 1x2 flip.  Without it
    (single-plane), every start pays the full patch-panel reconfiguration.

    ``placement`` picks which free servers a starting job gets: a name from
    ``{"first_fit", "contiguous"}`` or a callable ``(free, k) -> servers``
    (must return ``k`` distinct members of ``free``).
    """
    place = _PLACEMENTS[placement] if isinstance(placement, str) else placement
    state = ClusterState(n_servers=n_servers)
    pending: list[JobRequest] = sorted(jobs, key=lambda j: j.arrival_s)
    running: list[tuple[float, int]] = []  # (end_time, jid) heap
    records: dict[int, JobRecord] = {}
    # "Since the topology and parallelization strategy are calculated
    # off-line, we already know the sequence of job arrivals" (App. C):
    # the look-ahead plane provisions jobs in arrival order, one at a time,
    # starting at t=0 — before the jobs even arrive.
    provisioned: dict[int, float] = {}
    if lookahead:
        plane_free = 0.0
        for req in pending:
            provisioned[req.jid] = plane_free + reconfig_s
            plane_free = provisioned[req.jid]
    now = 0.0
    queue: list[JobRequest] = []
    i = 0

    def try_start():
        nonlocal queue
        started = True
        while started and queue:
            started = False
            req = queue[0]
            if len(state.free) >= req.n_servers:
                servers = tuple(place(state.free, req.n_servers))
                if len(set(servers)) != req.n_servers or not (
                    set(servers) <= state.free
                ):
                    raise ValueError(
                        f"placement returned {servers!r}; need "
                        f"{req.n_servers} distinct servers from the free set"
                    )
                state.free -= set(servers)
                rec = records[req.jid]
                rec.servers = servers
                if lookahead and req.jid in provisioned:
                    ready = provisioned[req.jid]
                    rec.start_s = max(now, ready) + FLIP_S
                else:
                    rec.start_s = now + reconfig_s
                rec.provision_ready_s = provisioned.get(req.jid, rec.start_s)
                rec.end_s = rec.start_s + req.duration_s
                heapq.heappush(running, (rec.end_s, req.jid))
                queue = queue[1:]
                started = True

    while i < len(pending) or queue or running:
        next_arrival = pending[i].arrival_s if i < len(pending) else float("inf")
        next_finish = running[0][0] if running else float("inf")
        if next_arrival <= next_finish:
            now = next_arrival
            req = pending[i]
            i += 1
            records[req.jid] = JobRecord(req=req)
            queue.append(req)
        else:
            now = next_finish
            _, jid = heapq.heappop(running)
            state.free |= set(records[jid].servers)
        try_start()

    return [records[j.jid] for j in jobs]


def mean_queueing_overhead(records: list[JobRecord]) -> float:
    return sum(r.queueing_s for r in records) / max(len(records), 1)
