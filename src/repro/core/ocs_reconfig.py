"""OCS-reconfig heuristic (Algorithm 5, App. E.4).

Periodically (every 50 ms) rebuilds the direct-connect topology from the
*unsatisfied* traffic demand: repeatedly give a link to the highest-demand
pair, discounting served demand by 1/2 per parallel link (Eq. 2's
exponential Discount), then 2-edge-replacement to restore connectivity.
A 10 ms reconfiguration pause is charged on every rebuild (§5.1).

The epoch scheduling itself lives in :class:`repro.core.simengine.SimEngine`
(``OCSPolicy`` scenarios and ``reconfig_drain``); this module only builds
one topology from one demand snapshot.  Importing ``ocs_topology`` /
``RECONFIG_WINDOW`` / ``RECONFIG_LATENCY`` from *this* module emits a
:class:`DeprecationWarning`; the same names are warning-free on
``repro.core.simengine``.
"""

from __future__ import annotations

import warnings

import networkx as nx
import numpy as np

_RECONFIG_WINDOW = 50e-3
_RECONFIG_LATENCY = 10e-3


def _ocs_topology(
    n: int, demand: np.ndarray, degree: int, ensure_connected: bool = True
) -> nx.MultiDiGraph:
    """Algorithm 5: greedy max-demand link allocation with halving."""
    t = demand.astype(np.float64).copy()
    np.fill_diagonal(t, 0.0)
    avail_tx = np.full(n, degree)
    avail_rx = np.full(n, degree)
    g = nx.MultiDiGraph()
    g.add_nodes_from(range(n))

    while True:
        masked = t.copy()
        masked[avail_tx <= 0, :] = -1.0
        masked[:, avail_rx <= 0] = -1.0
        np.fill_diagonal(masked, -1.0)
        i, j = np.unravel_index(np.argmax(masked), masked.shape)
        if masked[i, j] <= 0:
            break
        g.add_edge(int(i), int(j), kind="ocs")
        t[i, j] /= 2.0  # Discount(l) = sum 2^-x
        avail_tx[i] -= 1
        avail_rx[j] -= 1

    if ensure_connected:
        _two_edge_replacement(g, n, avail_tx, avail_rx)
    return g


def _two_edge_replacement(
    g: nx.MultiDiGraph, n: int, avail_tx: np.ndarray, avail_rx: np.ndarray
) -> None:
    """OWAN-style repair: connect weak components, first with spare
    interfaces, then by stealing a parallel link."""
    for _ in range(2 * n):
        comps = list(nx.weakly_connected_components(nx.DiGraph(g)))
        if len(comps) <= 1:
            return
        a_set, b_set = comps[0], comps[1]
        src = next((v for v in a_set if avail_tx[v] > 0), None)
        dst = next((v for v in b_set if avail_rx[v] > 0), None)
        if src is not None and dst is not None:
            g.add_edge(src, dst, kind="repair")
            avail_tx[src] -= 1
            avail_rx[dst] -= 1
            # also the reverse to keep strong reachability cheap
            if avail_tx[dst] > 0 and avail_rx[src] > 0:
                g.add_edge(dst, src, kind="repair")
                avail_tx[dst] -= 1
                avail_rx[src] -= 1
            continue
        # True 2-edge replacement (OWAN): remove one intra-A and one intra-B
        # edge, rewire them across the cut.  Degrees are preserved.
        edge_a = next(
            ((u, v) for u, v in g.edges() if u in a_set and v in a_set), None
        )
        edge_b = next(
            ((x, y) for x, y in g.edges() if x in b_set and y in b_set), None
        )
        if edge_a is None or edge_b is None:
            return
        (u, v), (x, y) = edge_a, edge_b
        g.remove_edge(u, v, key=next(iter(g[u][v])))
        g.remove_edge(x, y, key=next(iter(g[x][y])))
        g.add_edge(u, y, kind="repair")
        g.add_edge(x, v, kind="repair")


# -- deprecated shim surface -------------------------------------------------

_DEPRECATED_SHIMS = {
    "ocs_topology": lambda: _ocs_topology,
    "RECONFIG_WINDOW": lambda: _RECONFIG_WINDOW,
    "RECONFIG_LATENCY": lambda: _RECONFIG_LATENCY,
}


def __getattr__(name: str):
    shim = _DEPRECATED_SHIMS.get(name)
    if shim is not None:
        warnings.warn(
            f"repro.core.ocs_reconfig.{name} is deprecated; import it from "
            "repro.core.simengine (or drive OCS epochs via "
            "SimEngine + OCSPolicy) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return shim()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
