"""Deterministic synthetic data pipeline.

Stateless: ``batch_for_step(step)`` derives every batch from (seed, step), so
checkpoint/restart and elastic rescaling never need data-state checkpoints —
restarting at step k regenerates exactly the batch stream from k.  A
background prefetch thread keeps ``depth`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from ..configs.base import ArchConfig, ShapeSpec


@dataclass(frozen=True)
class DataSpec:
    cfg: ArchConfig
    shape: ShapeSpec
    seed: int = 0
    # Multi-host sharding: this process owns batch rows
    # [process_index::process_count] (single-process here, but the layout
    # matches jax.process_index() usage on real pods).
    process_index: int = 0
    process_count: int = 1


def batch_for_step(spec: DataSpec, step: int) -> dict:
    """Deterministic batch for a global step (numpy, host-side)."""
    cfg, shape = spec.cfg, spec.shape
    rng = np.random.default_rng(np.uint64(spec.seed * 1_000_003 + step))
    B, S = shape.global_batch, shape.seq_len
    rows = range(spec.process_index, B, spec.process_count)
    nb = len(list(rows))

    if cfg.family == "audio":
        return {
            "frames": rng.standard_normal((nb, S, cfg.d_model), dtype=np.float32),
            "labels": rng.integers(0, cfg.vocab, (nb, S), dtype=np.int32),
        }
    batch = {"tokens": rng.integers(0, cfg.vocab, (nb, S), dtype=np.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (nb, cfg.img_tokens, cfg.d_model), dtype=np.float32
        )
    return batch


class Prefetcher:
    """Background-thread batch prefetch with bounded depth."""

    def __init__(self, spec: DataSpec, start_step: int, depth: int = 2):
        self.spec = spec
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_for_step(self.spec, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
