"""Model primitives shared by all assigned architectures.

Pure-function style: every layer is ``f(params_subtree, inputs, cfg) -> out``
so stacks can be driven by ``lax.scan`` over stacked parameters.  Norms and
softmax accumulate in fp32; matmul inputs are cfg.activation_dtype.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# Norms / positional
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    from ..parallel.options import get_options

    dt = x.dtype
    xf = x.astype(jnp.float32)
    scale = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if get_options().lowp_norm and dt != jnp.float32:
        # statistics in fp32, elementwise scaling in bf16: the residual
        # stream never materializes in fp32 (§Perf memory lever).
        return x * scale.astype(dt) * (1.0 + w.astype(jnp.float32)).astype(dt)
    return (xf * scale * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / bidirectional / sliding-window, self / cross)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, cross: bool = False):
    dt = jnp.dtype(cfg.param_dtype)
    hd = cfg.hd
    kq, kk, kv, ko, kg = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ko, cfg.n_heads * hd, cfg.d_model, dt),
        "norm": jnp.zeros((cfg.d_model,), dt),
    }
    if cross:
        # Llama-3.2-vision style gated cross-attention.
        p["gate"] = jnp.zeros((), dt)
        p["xnorm"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _sdpa(q, k, v, mask):
    """q: (B, S, KV, G, D); k/v: (B, T, KV, D); mask: broadcastable (S, T)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def causal_mask(s: int, t: int, q_offset=0, window: int = 0):
    """(s, t) bool mask; query i attends key j iff j <= i+off and within
    window (if window > 0)."""
    qi = jnp.arange(s)[:, None] + q_offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def _chunked_sdpa(qg, k, v, *, causal: bool, window: int, chunk: int):
    """Flash-style online-softmax attention over KV chunks (XLA path).

    Never materializes the (S, T) score matrix — peak intermediate is
    (B, S, KV, G, chunk).  The Pallas kernel (kernels/flash_attention.py)
    is the TPU-native equivalent; this keeps the dry-run HLO honest.
    qg: (B, S, KV, G, D); k/v: (B, T, KV, D).
    """
    B, S, KV, G, D = qg.shape
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (T + pad) // chunk
    scale = 1.0 / math.sqrt(D)
    k_c = jnp.moveaxis(k.reshape(B, nc, chunk, KV, D), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nc, chunk, KV, D), 1, 0)
    q_pos = jnp.arange(S)[:, None]

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kc).astype(jnp.float32) * scale
        k_pos = ci * chunk + jnp.arange(chunk)[None, :]
        msk = k_pos < T
        if causal:
            msk &= k_pos <= q_pos
        if window > 0:
            msk &= k_pos > q_pos - window
        s = jnp.where(msk[None, :, None, None, :], s, -1e30)
        m2 = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m2)
        p_ = jnp.exp(s - m2[..., None])
        l2 = alpha * l + p_.sum(axis=-1)
        acc2 = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p_.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m2, l2, acc2), None

    init = (
        jnp.full((B, S, KV, G), -1e30, jnp.float32),
        jnp.zeros((B, S, KV, G), jnp.float32),
        jnp.zeros((B, S, KV, G, D), jnp.float32),
    )
    (m, l, acc), _ = lax.scan(
        jax.checkpoint(body), init, (k_c, v_c, jnp.arange(nc))
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qg.dtype)


def attention(p, x, cfg, *, mask=None, causal=True, window=0, positions=None,
              kv_x=None, use_rope=True):
    """Self- or cross-attention over full sequences (train / prefill).

    x: (B, S, d_model); kv_x: (B, T, d_model) for cross-attention.
    ``mask`` overrides (causal, window) for the naive path.
    Returns (B, S, d_model).
    """
    from ..parallel.options import get_options

    hd = cfg.hd
    src = x if kv_x is None else kv_x
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"]), cfg.n_heads, hd)
    k = _split_heads(jnp.einsum("btd,de->bte", src, p["wk"]), cfg.n_kv_heads, hd)
    v = _split_heads(jnp.einsum("btd,de->bte", src, p["wv"]), cfg.n_kv_heads, hd)
    if use_rope and kv_x is None:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(*q.shape[:2], cfg.n_kv_heads, g, hd)

    opts = get_options()
    if opts.attention_impl == "chunked" and kv_x is None:
        out = _chunked_sdpa(
            qg, k, v, causal=causal, window=window, chunk=opts.attention_chunk
        )
    else:
        if mask is None and kv_x is None and (causal or window):
            mask = causal_mask(x.shape[1], src.shape[1], window=window)
        out = _sdpa(qg, k, v, mask)
    out = out.reshape(*out.shape[:2], cfg.n_heads * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def attention_decode(p, x, cache_k, cache_v, pos, cfg, *, window: int = 0):
    """One-token decode against a KV cache.

    x: (B, d_model); cache_k/v: (B, KV, T, D); pos: scalar current index.
    Returns (out (B, d_model), new_k, new_v).
    """
    hd = cfg.hd
    B = x.shape[0]
    q = _split_heads(jnp.einsum("bd,de->be", x, p["wq"]), cfg.n_heads, hd)
    k = _split_heads(jnp.einsum("bd,de->be", x, p["wk"]), cfg.n_kv_heads, hd)
    v = _split_heads(jnp.einsum("bd,de->be", x, p["wv"]), cfg.n_kv_heads, hd)
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q[:, None], posb, cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], posb, cfg.rope_theta)[:, 0]

    T = cache_k.shape[2]
    if window > 0 and window == T:
        # Rolling window cache: slot = pos % window.
        slot = pos % T
    else:
        slot = pos
    # All start indices must share one dtype; literal zeros would promote
    # to int64 when x64 is enabled (the planner pins it) while a traced
    # `pos` stays int32, so build them from slot's own dtype.
    slot = jnp.asarray(slot)
    zero = jnp.zeros((), dtype=slot.dtype)
    new_k = lax.dynamic_update_slice(
        cache_k, k[:, :, None, :].astype(cache_k.dtype), (zero, zero, slot, zero)
    )
    new_v = lax.dynamic_update_slice(
        cache_v, v[:, :, None, :].astype(cache_v.dtype), (zero, zero, slot, zero)
    )

    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg, new_k).astype(jnp.float32) * scale
    t_idx = jnp.arange(T)
    if window > 0 and window == T:
        valid = (t_idx <= slot) | (pos >= T)  # whole ring valid once wrapped
    else:
        valid = t_idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs.astype(new_v.dtype), new_v)
    out = out.reshape(B, cfg.n_heads * hd)
    return jnp.einsum("be,ed->bd", out, p["wo"]), new_k, new_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, kind: str = "swiglu", d_ff: int | None = None):
    dt = jnp.dtype(cfg.param_dtype)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wg": dense_init(k1, cfg.d_model, d_ff, dt),
            "wu": dense_init(k2, cfg.d_model, d_ff, dt),
            "wd": dense_init(k3, d_ff, cfg.d_model, dt),
            "norm": jnp.zeros((cfg.d_model,), dt),
        }
    return {  # gelu
        "w1": dense_init(k1, cfg.d_model, d_ff, dt),
        "w2": dense_init(k2, d_ff, cfg.d_model, dt),
        "norm": jnp.zeros((cfg.d_model,), dt),
    }


def mlp(p, x):
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wg"]))
        h = h * jnp.einsum("...d,df->...f", x, p["wu"])
        return jnp.einsum("...f,fd->...d", h, p["wd"])
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w1"]))
    return jnp.einsum("...f,fd->...d", h, p["w2"])


# ---------------------------------------------------------------------------
# Mixture-of-Experts (capacity-based token dropping, sort-free dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s = 1.0 / math.sqrt(D)
    return {
        "router": dense_init(kr, D, E, jnp.float32),
        "wg": truncated_normal(kg, (E, D, F), s, dt),
        "wu": truncated_normal(ku, (E, D, F), s, dt),
        "wd": truncated_normal(kd, (E, F, D), 1.0 / math.sqrt(F), dt),
        "norm": jnp.zeros((D,), dt),
    }


def moe(p, x, cfg):
    """Top-k routed MoE with per-expert capacity (GShard-style dropping).

    Dispatch uses argsort + scatter into an (E, C, D) buffer — no O(N*E*C)
    one-hot einsum — then three batched expert matmuls, then gather+combine.
    Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = lax.top_k(probs, K)  # (N, K)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    token_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(token_frac * prob_frac) / K

    C = max(1, int(cfg.capacity_factor * N * K / E))

    flat_e = top_idx.reshape(-1)  # (N*K,)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(N * K) - starts[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, pos_in_e, 0)

    from ..parallel.act_sharding import constrain as _constrain
    from ..parallel.options import get_options as _get_options

    tok_of = order // K  # source token per dispatch entry
    dispatched = jnp.where(keep[:, None], xt[tok_of], 0.0)
    if _get_options().moe_gather_constrain:
        dispatched = _constrain(dispatched, "nd")
    buf = jnp.zeros((E, C, D), xt.dtype)
    buf = buf.at[sorted_e, slot].add(dispatched, mode="drop")

    if _get_options().moe_constrain:
        buf = _constrain(buf, "ecd")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    if _get_options().moe_constrain:
        y = _constrain(y, "ecd")

    gathered = y[sorted_e, slot]  # (N*K, D)
    w = top_vals.reshape(-1)[order]
    gathered = gathered * jnp.where(keep, w, 0.0)[:, None].astype(y.dtype)
    out = jnp.zeros((N, D), y.dtype).at[tok_of].add(gathered, mode="drop")
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Linear recurrences (chunked associative scan): Mamba-1 + RG-LRU
# ---------------------------------------------------------------------------


def chunked_linear_scan(a, b, h0, chunk: int = 256):
    """Elementwise recurrence h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: (B, L, ...); h0: (B, ...).  Returns (h_all (B, L, ...), h_last).
    Chunking bounds the materialized prefix tree to (B, chunk, ...) per step
    so 32k/524k sequences don't blow activation memory.
    """
    Bsz, L = a.shape[0], a.shape[1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        a = jnp.concatenate(
            [a, jnp.ones((Bsz, pad, *a.shape[2:]), a.dtype)], axis=1
        )
        b = jnp.concatenate(
            [b, jnp.zeros((Bsz, pad, *b.shape[2:]), b.dtype)], axis=1
        )
    nc = a.shape[1] // chunk
    a_c = jnp.moveaxis(a.reshape(Bsz, nc, chunk, *a.shape[2:]), 1, 0)
    b_c = jnp.moveaxis(b.reshape(Bsz, nc, chunk, *b.shape[2:]), 1, 0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def body(h, ab):
        ac, bc = ab  # (B, chunk, ...)
        aa, bb = lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = aa * h[:, None] + bb
        return h_all[:, -1], h_all

    from ..parallel.options import get_options

    if get_options().scan_impl == "assoc_ckpt":
        # recompute the within-chunk tree in the backward pass; only the
        # chunk-boundary carries are saved (§Perf memory lever).
        body = jax.checkpoint(body)
    h_last, h_all = lax.scan(body, h0, (a_c, b_c))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(Bsz, nc * chunk, *a.shape[2:])
    if pad:
        h_all = h_all[:, :L]
    return h_all, h_last


def causal_conv1d(x, w, prev=None):
    """Depthwise causal conv along time.  x: (B, L, D); w: (W, D).

    ``prev``: (B, W-1, D) carried context for decode/chunked prefill."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W)
    )
    new_prev = xp[:, -(W - 1) :] if W > 1 else prev
    return out, new_prev


def init_mamba(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    D, DI, ST, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.tile(jnp.arange(1, ST + 1, dtype=jnp.float32), (DI, 1)))
    return {
        "w_in": dense_init(ks[0], D, 2 * DI, dt),
        "conv_w": truncated_normal(ks[1], (cfg.d_conv, DI), 1.0 / math.sqrt(cfg.d_conv), dt),
        "conv_b": jnp.zeros((DI,), dt),
        "w_xdbc": dense_init(ks[2], DI, R + 2 * ST, dt),
        "w_dt": dense_init(ks[3], R, DI, dt),
        "b_dt": jnp.full((DI,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": a_init,
        "d_skip": jnp.ones((DI,), jnp.float32),
        "w_out": dense_init(ks[4], DI, D, dt),
        "norm": jnp.zeros((D,), dt),
    }


def mamba_ssm(p, xc, cfg, h0=None, chunk: int = 256):
    """Selective scan given the post-conv activations xc: (B, L, DI).

    Two implementations (parallel.options.scan_impl):
    * "assoc" (baseline): materializes (B, chunk, DI, ST) decay/drive and
      runs a chunked associative scan — parallel but HBM-heavy,
    * "seq": sequential lax.scan over time computing decay/drive on the fly
      — the HLO analogue of the fused Pallas kernel's traffic profile.
    Returns (y (B, L, DI), h_last (B, DI, ST) fp32)."""
    from ..parallel.options import get_options

    Bsz, L, DI = xc.shape
    ST, R = cfg.ssm_state, cfg.dt_rank_
    xdbc = jnp.einsum("bld,de->ble", xc, p["w_xdbc"])
    dt_r, b_ssm, c_ssm = jnp.split(xdbc, [R, R + ST], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_r, p["w_dt"]).astype(jnp.float32)
        + p["b_dt"]
    )  # (B, L, DI)
    a = -jnp.exp(p["a_log"])  # (DI, ST)
    if h0 is None:
        h0 = jnp.zeros((Bsz, DI, ST), jnp.float32)

    if get_options().scan_impl == "seq" and L > 1:
        xs = (
            jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(b_ssm.astype(jnp.float32), 1, 0),
            jnp.moveaxis(c_ssm.astype(jnp.float32), 1, 0),
        )

        def step(h, ts):
            x_t, dt_t, b_t, c_t = ts
            h = jnp.exp(dt_t[..., None] * a) * h + (dt_t * x_t)[..., None] * b_t[
                :, None, :
            ]
            y_t = jnp.einsum("bds,bs->bd", h, c_t) + p["d_skip"] * x_t
            return h, y_t

        h_last, ys = lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1)
        return y.astype(xc.dtype), h_last

    decay = jnp.exp(dt[..., None] * a)  # (B, L, DI, ST)
    drive = (dt * xc.astype(jnp.float32))[..., None] * b_ssm.astype(jnp.float32)[
        :, :, None, :
    ]  # (B, L, DI, ST)
    h_all, h_last = chunked_linear_scan(decay, drive, h0, chunk=chunk)
    y = jnp.einsum("blds,bls->bld", h_all, c_ssm.astype(jnp.float32))
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    return y.astype(xc.dtype), h_last


def mamba_block(p, x, cfg, state=None, chunk: int = 256):
    """Full Mamba-1 block.  x: (B, L, D).  state: None (train) or dict with
    'conv' (B, W-1, DI) and 'ssm' (B, DI, ST) for stateful prefill/decode.
    Returns (out, new_state)."""
    xz = jnp.einsum("bld,de->ble", x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    prev = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(xi, p["conv_w"], prev)
    xc = jax.nn.silu(xc + p["conv_b"])
    h0 = state["ssm"] if state is not None else None
    y, h_last = mamba_ssm(p, xc, cfg, h0=h0, chunk=chunk)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bld,de->ble", y, p["w_out"])
    new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_last}
    return out, new_state


def init_rglru(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    D, DI = cfg.d_model, cfg.d_inner
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], D, DI, dt),
        "w_y": dense_init(ks[1], D, DI, dt),  # gelu branch
        "conv_w": truncated_normal(ks[2], (4, DI), 0.5, dt),
        "conv_b": jnp.zeros((DI,), dt),
        "w_input_gate": dense_init(ks[3], DI, DI, dt),
        "w_rec_gate": dense_init(ks[4], DI, DI, dt),
        "lambda_p": jnp.linspace(0.9, 5.0, DI, dtype=jnp.float32),  # softplus domain
        "w_out": dense_init(ks[5], DI, D, dt),
        "norm": jnp.zeros((D,), dt),
    }


RGLRU_C = 8.0


def rglru_block(p, x, cfg, state=None, chunk: int = 256):
    """Griffin recurrent block: conv1d -> RG-LRU, gated by a GeLU branch.

    x: (B, L, D); state: None or {'conv': (B, 3, DI), 'lru': (B, DI) fp32}.
    Returns (out, new_state)."""
    xb = jnp.einsum("bld,de->ble", x, p["w_x"])
    yb = jax.nn.gelu(jnp.einsum("bld,de->ble", x, p["w_y"]))
    prev = state["conv"] if state is not None else None
    xc, new_conv = causal_conv1d(xb, p["conv_w"], prev)
    xc = xc + p["conv_b"]

    i_gate = jax.nn.sigmoid(
        jnp.einsum("bld,de->ble", xc, p["w_input_gate"]).astype(jnp.float32)
    )
    r_gate = jax.nn.sigmoid(
        jnp.einsum("bld,de->ble", xc, p["w_rec_gate"]).astype(jnp.float32)
    )
    log_a = -RGLRU_C * r_gate * jax.nn.softplus(p["lambda_p"])
    a = jnp.exp(log_a)
    gated_x = i_gate * xc.astype(jnp.float32)
    drive = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = state["lru"] if state is not None else jnp.zeros(
        (x.shape[0], cfg.d_inner), jnp.float32
    )
    h_all, h_last = chunked_linear_scan(a, drive, h0, chunk=chunk)
    out = jnp.einsum("bld,de->ble", (h_all.astype(x.dtype) * yb), p["w_out"])
    new_state = {"conv": new_conv.astype(x.dtype), "lru": h_last}
    return out, new_state
