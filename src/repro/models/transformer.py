"""Transformer stacks: dense (llama-arch), MoE (qwen3-arch), VLM
(cross-attention image blocks), and encoder-only audio (hubert).

Layer stacks are ``lax.scan`` over stacked parameters (keeps HLO size O(1)
in depth) with configurable rematerialization.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..parallel.act_sharding import constrain
from . import layers as L


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full"


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_self_block(key, cfg: ArchConfig, mlp_kind: str = "swiglu"):
    ka, km = jax.random.split(key)
    blk = {"attn": L.init_attention(ka, cfg)}
    if cfg.family == "moe":
        blk["moe"] = L.init_moe(km, cfg)
    else:
        blk["mlp"] = L.init_mlp(km, cfg, kind=mlp_kind)
    return blk


def init_cross_block(key, cfg: ArchConfig):
    ka, km = jax.random.split(key)
    return {
        "attn": L.init_attention(ka, cfg, cross=True),
        "mlp": L.init_mlp(km, cfg, kind="swiglu"),
    }


def init_params(key, cfg: ArchConfig):
    ke, kb, kh, kx = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict = {"final_norm": jnp.zeros((cfg.d_model,), dt)}

    if cfg.family != "audio":
        params["embed"] = L.truncated_normal(ke, (cfg.vocab, cfg.d_model), 0.02, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab, dt)

    mlp_kind = "gelu" if cfg.family == "audio" else "swiglu"
    if cfg.family == "vlm":
        n_super = cfg.n_layers // cfg.cross_attn_every
        inner = cfg.cross_attn_every - 1
        params["blocks"] = {
            "self": _stack_init(
                kb, n_super,
                lambda k: _stack_init(k, inner, partial(init_self_block, cfg=cfg)),
            ),
            "cross": _stack_init(kx, n_super, partial(init_cross_block, cfg=cfg)),
        }
    else:
        params["blocks"] = _stack_init(
            kb, cfg.n_layers, partial(init_self_block, cfg=cfg, mlp_kind=mlp_kind)
        )
    return params


def _self_block_apply(blk, x, cfg, mask, positions):
    h = x + L.attention(
        blk["attn"], L.rms_norm(x, blk["attn"]["norm"]), cfg,
        mask=mask, causal=cfg.family != "audio", window=cfg.attn_window,
        positions=positions,
        use_rope=cfg.family != "audio",
    )
    if "moe" in blk:
        y, aux = L.moe(blk["moe"], L.rms_norm(h, blk["moe"]["norm"]), cfg)
        return h + y, aux
    y = L.mlp(blk["mlp"], L.rms_norm(h, blk["mlp"]["norm"]))
    return h + y, jnp.float32(0.0)


def _cross_block_apply(blk, x, img, cfg):
    att = L.attention(
        blk["attn"], L.rms_norm(x, blk["attn"]["xnorm"]), cfg,
        kv_x=img, use_rope=False,
    )
    h = x + jnp.tanh(blk["attn"]["gate"].astype(jnp.float32)).astype(x.dtype) * att
    y = L.mlp(blk["mlp"], L.rms_norm(h, blk["mlp"]["norm"]))
    return h + y


def forward(
    params,
    cfg: ArchConfig,
    tokens=None,
    frames=None,
    image_embeds=None,
    remat: str = "full",
):
    """Full-sequence forward -> (logits (B, S, V), aux_loss)."""
    if cfg.family == "audio":
        x = frames
        S = x.shape[1]
        mask = None
    else:
        x = constrain(
            params["embed"][tokens].astype(jnp.dtype(cfg.activation_dtype)), "btd"
        )
        S = tokens.shape[1]
        mask = None  # attention() builds/streams the mask per impl
    positions = jnp.arange(S)[None, :]

    def block_fn(carry, blk):
        h, aux = carry
        h2, a = _self_block_apply(blk, constrain(h, "btd"), cfg, mask, positions)
        return (constrain(h2, "btd"), aux + a), None

    block_fn = _remat(block_fn, remat)

    if cfg.family == "vlm":
        img = image_embeds.astype(x.dtype)

        def super_fn(carry, blk):
            inner_carry, _ = lax.scan(block_fn, carry, blk["self"])
            h, aux = inner_carry
            h = _cross_block_apply(blk["cross"], h, img, cfg)
            return (h, aux), None

        (x, aux), _ = lax.scan(_remat(super_fn, "none"), (x, jnp.float32(0.0)),
                               params["blocks"])
    else:
        (x, aux), _ = lax.scan(block_fn, (x, jnp.float32(0.0)), params["blocks"])

    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, aux


def hidden_forward(params, cfg, tokens=None, frames=None, image_embeds=None,
                   remat: str = "full"):
    """Like forward() but stops before the LM head (for chunked losses)."""
    # Reuse forward's plumbing by temporarily removing the head projection:
    # duplicated minimal body to avoid computing the big logits einsum.
    if cfg.family == "audio":
        x = frames
        S = x.shape[1]
        mask = None
    else:
        x = constrain(
            params["embed"][tokens].astype(jnp.dtype(cfg.activation_dtype)), "btd"
        )
        S = tokens.shape[1]
        mask = None  # attention() builds/streams the mask per impl
    positions = jnp.arange(S)[None, :]

    def block_fn(carry, blk):
        h, aux = carry
        h2, a = _self_block_apply(blk, constrain(h, "btd"), cfg, mask, positions)
        return (constrain(h2, "btd"), aux + a), None

    block_fn = _remat(block_fn, remat)
    if cfg.family == "vlm":
        img = image_embeds.astype(x.dtype)

        def super_fn(carry, blk):
            inner_carry, _ = lax.scan(block_fn, carry, blk["self"])
            h, aux = inner_carry
            h = _cross_block_apply(blk["cross"], h, img, cfg)
            return (h, aux), None

        (x, aux), _ = lax.scan(super_fn, (x, jnp.float32(0.0)), params["blocks"])
    else:
        (x, aux), _ = lax.scan(block_fn, (x, jnp.float32(0.0)), params["blocks"])
    return L.rms_norm(x, params["final_norm"]), aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, tokens, image_embeds=None, pad_to: int = 0):
    """Full-sequence forward that also materializes the KV cache.

    ``pad_to``: pad the cache sequence dim to this length so decode can
    append (serving uses max_len; the dry-run measures prefill alone).
    Returns (last-token logits (B, V), cache dict matching cache_specs)."""
    act = jnp.dtype(cfg.activation_dtype)
    x = constrain(params["embed"][tokens].astype(act), "btd")
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    mask = None  # attention() builds/streams the mask per impl
    hd = cfg.hd

    def kv_of(blk, h):
        src = L.rms_norm(h, blk["attn"]["norm"])
        k = L._split_heads(jnp.einsum("btd,de->bte", src, blk["attn"]["wk"]),
                           cfg.n_kv_heads, hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        v = L._split_heads(jnp.einsum("btd,de->bte", src, blk["attn"]["wv"]),
                           cfg.n_kv_heads, hd)
        return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # (B, KV, S, D)

    def block_fn(carry, blk):
        h, aux = carry
        h = constrain(h, "btd")
        k, v = kv_of(blk, h)
        h2, a = _self_block_apply(blk, h, cfg, mask, positions)
        return (constrain(h2, "btd"), aux + a), (k.astype(act), v.astype(act))

    if cfg.family == "vlm":
        img = image_embeds.astype(act)

        def xkv_of(blk):
            k = L._split_heads(jnp.einsum("btd,de->bte", img, blk["attn"]["wk"]),
                               cfg.n_kv_heads, hd)
            v = L._split_heads(jnp.einsum("btd,de->bte", img, blk["attn"]["wv"]),
                               cfg.n_kv_heads, hd)
            return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

        def super_fn(carry, blk):
            inner_carry, kv = lax.scan(block_fn, carry, blk["self"])
            h, aux = inner_carry
            xk, xv = xkv_of(blk["cross"])
            h = _cross_block_apply(blk["cross"], h, img, cfg)
            return (h, aux), (kv, (xk.astype(act), xv.astype(act)))

        (x, _), (kv, xkv) = lax.scan(super_fn, (x, jnp.float32(0.0)),
                                     params["blocks"])
        ks, vs = kv  # (n_super, inner, B, KV, S, D)
        cache = {
            "k": ks.reshape(-1, *ks.shape[2:]),
            "v": vs.reshape(-1, *vs.shape[2:]),
            "xk": xkv[0],
            "xv": xkv[1],
        }
    else:
        (x, _), (ks, vs) = lax.scan(block_fn, (x, jnp.float32(0.0)),
                                    params["blocks"])
        cache = {"k": ks, "v": vs}

    if pad_to > S:
        pad = [(0, 0)] * 5
        pad[3] = (0, pad_to - S)
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)

    x = L.rms_norm(x[:, -1], params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bd,dv->bv", x, head)
    return logits, cache


def _cross_decode(blk, x, xk, xv, cfg):
    """One-token cross-attention against cached image K/V."""
    import math as _m

    hd = cfg.hd
    B = x.shape[0]
    xin = L.rms_norm(x, blk["attn"]["xnorm"])
    q = L._split_heads(jnp.einsum("bd,de->be", xin, blk["attn"]["wq"]),
                       cfg.n_heads, hd)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, g, hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg, xk).astype(jnp.float32)
    scores = scores / _m.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs.astype(xv.dtype), xv)
    att = jnp.einsum("be,ed->bd", out.reshape(B, -1), blk["attn"]["wo"])
    h = x + jnp.tanh(blk["attn"]["gate"].astype(jnp.float32)).astype(x.dtype) * att
    return h + L.mlp(blk["mlp"], L.rms_norm(h, blk["mlp"]["norm"]))


def decode_step(params, cfg: ArchConfig, token, pos, cache):
    """One decode step.  token: (B,) int32; pos: scalar; cache per
    cache_specs.  Returns (logits (B, V), new cache)."""
    x = params["embed"][token].astype(jnp.dtype(cfg.activation_dtype))

    def block_fn(h, xs):
        blk, ck, cv = xs
        h = constrain(h, "bd")
        att, nk, nv = L.attention_decode(
            blk["attn"], L.rms_norm(h, blk["attn"]["norm"]), ck, cv, pos, cfg,
            window=cfg.attn_window,
        )
        h = h + att
        if "moe" in blk:
            y, _ = L.moe(blk["moe"], L.rms_norm(h, blk["moe"]["norm"])[:, None],
                         cfg)
            h = h + y[:, 0]
        else:
            h = h + L.mlp(blk["mlp"], L.rms_norm(h, blk["mlp"]["norm"]))
        return h, (nk, nv)

    if cfg.family == "vlm":
        n_super = cfg.n_layers // cfg.cross_attn_every
        inner = cfg.cross_attn_every - 1
        ks = cache["k"].reshape(n_super, inner, *cache["k"].shape[1:])
        vs = cache["v"].reshape(n_super, inner, *cache["v"].shape[1:])

        def super_fn(h, xs):
            blk, ck, cv, xk, xv = xs
            h, kv = lax.scan(block_fn, h, (blk["self"], ck, cv))
            h = _cross_decode(blk["cross"], h, xk, xv, cfg)
            return h, kv

        x, kv = lax.scan(super_fn, x,
                         (params["blocks"], ks, vs, cache["xk"], cache["xv"]))
        new_cache = {
            "k": kv[0].reshape(-1, *kv[0].shape[2:]),
            "v": kv[1].reshape(-1, *kv[1].shape[2:]),
            "xk": cache["xk"],
            "xv": cache["xv"],
        }
    else:
        x, kv = lax.scan(block_fn, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": kv[0], "v": kv[1]}

    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bd,dv->bv", x, head)
    return logits, new_cache
