"""Uniform model facade used by train/serve/launch.

init(key, cfg) / loss_fn(params, batch, cfg) / prefill / decode_step all
dispatch on cfg.family.  Losses are next-token CE for decoder LMs and
masked-frame CE for the audio encoder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from . import recurrent, transformer


def init(key, cfg: ArchConfig):
    if cfg.family == "ssm":
        return recurrent.init_mamba_params(key, cfg)
    if cfg.family == "hybrid":
        return recurrent.init_griffin_params(key, cfg)
    return transformer.init_params(key, cfg)


def param_specs(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def forward(params, batch, cfg: ArchConfig, remat: str = "full"):
    if cfg.family == "ssm":
        return recurrent.mamba_forward(params, cfg, batch["tokens"], remat=remat)
    if cfg.family == "hybrid":
        return recurrent.griffin_forward(params, cfg, batch["tokens"], remat=remat)
    if cfg.family == "audio":
        return transformer.forward(params, cfg, frames=batch["frames"], remat=remat)
    return transformer.forward(
        params, cfg, tokens=batch.get("tokens"),
        image_embeds=batch.get("image_embeds"), remat=remat,
    )


def _xent(logits, targets, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _hidden_xent_chunked(x, head, targets, mask, chunk: int):
    """CE computed over sequence chunks so (B, S, V) logits are never fully
    materialized (memory-roofline optimization; see EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xs = (
        jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0),
        jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0),
        jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0),
    )

    def body(acc, xs_c):
        xc, tc, mc = xs_c
        logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = ((logz - gold) * mc).sum()
        return (acc[0] + nll, acc[1] + mc.sum()), None

    body = jax.checkpoint(body)
    (nll, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return nll / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, remat: str = "full",
            loss_chunk: int = 0, aux_weight: float = 0.01):
    """Scalar training loss (+ metrics dict)."""
    if cfg.family == "audio":
        logits, aux = forward(params, batch, cfg, remat=remat)
        targets = batch["labels"]
        mask = jnp.ones(targets.shape, jnp.float32)
        loss = _xent(logits, targets, mask)
        return loss, {"xent": loss}

    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones(tokens[:, 1:].shape, jnp.float32),
         jnp.zeros(tokens[:, :1].shape, jnp.float32)], axis=1,
    )
    if loss_chunk > 0:
        if cfg.family == "ssm" or cfg.family == "hybrid":
            # recurrent stacks keep their own head; fall through to full CE
            logits, aux = forward(params, batch, cfg, remat=remat)
            loss = _xent(logits, targets, mask)
        else:
            x, aux = transformer.hidden_forward(
                params, cfg, tokens=batch.get("tokens"),
                image_embeds=batch.get("image_embeds"), remat=remat,
            )
            head = params.get("lm_head")
            if head is None:
                head = params["embed"].T
            loss = _hidden_xent_chunked(x, head, targets, mask, loss_chunk)
    else:
        logits, aux = forward(params, batch, cfg, remat=remat)
        loss = _xent(logits, targets, mask)
    total = loss + aux_weight * aux
    return total, {"xent": loss, "aux": aux}


def prefill(params, batch, cfg: ArchConfig, pad_to: int = 0):
    if cfg.family == "ssm":
        return recurrent.mamba_prefill(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return recurrent.griffin_prefill(params, cfg, batch["tokens"])
    if cfg.family == "audio":
        logits, _ = transformer.forward(params, cfg, frames=batch["frames"])
        return logits, {}
    return transformer.prefill(
        params, cfg, batch["tokens"], image_embeds=batch.get("image_embeds"),
        pad_to=pad_to,
    )


def decode_step(params, batch, cfg: ArchConfig):
    token, pos, cache = batch["token"], batch["pos"], batch["cache"]
    if cfg.family == "ssm":
        return recurrent.mamba_decode_step(params, cfg, token, pos, cache)
    if cfg.family == "hybrid":
        return recurrent.griffin_decode_step(params, cfg, token, pos, cache)
    return transformer.decode_step(params, cfg, token, pos, cache)
