"""DLRM in JAX (paper §2.1, List 1) — the paper's flagship workload.

Embedding tables + bottom/top MLPs + pairwise dot interaction, matching
facebookresearch/dlrm's architecture at configurable scale.  Used by the
testbed-reproduction example and the embedding-bag kernel.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import layers as L


@dataclass(frozen=True)
class DLRMConfig:
    n_tables: int = 8
    rows_per_table: int = 1000
    embed_dim: int = 32
    dense_features: int = 13
    bottom_mlp: tuple[int, ...] = (64, 32)
    top_mlp: tuple[int, ...] = (64, 1)


def init(key, cfg: DLRMConfig):
    keys = jax.random.split(key, 3 + cfg.n_tables)
    tables = jnp.stack(
        [
            L.truncated_normal(
                keys[i],
                (cfg.rows_per_table, cfg.embed_dim),
                1.0 / math.sqrt(cfg.embed_dim),
                jnp.float32,
            )
            for i in range(cfg.n_tables)
        ]
    )

    def mlp_init(k, dims):
        ws = []
        ks = jax.random.split(k, len(dims) - 1)
        for i in range(len(dims) - 1):
            ws.append(
                {
                    "w": L.dense_init(ks[i], dims[i], dims[i + 1], jnp.float32),
                    "b": jnp.zeros((dims[i + 1],), jnp.float32),
                }
            )
        return ws

    n_pairs = (cfg.n_tables + 1) * cfg.n_tables // 2
    top_in = cfg.embed_dim + n_pairs
    return {
        "tables": tables,
        "bottom": mlp_init(keys[-2], (cfg.dense_features, *cfg.bottom_mlp, cfg.embed_dim)),
        "top": mlp_init(keys[-1], (top_in, *cfg.top_mlp)),
    }


def _mlp(ws, x, final_sigmoid=False):
    for i, lyr in enumerate(ws):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(ws) - 1:
            x = jax.nn.relu(x)
    return jax.nn.sigmoid(x) if final_sigmoid else x


def forward(params, dense, sparse_ids, cfg: DLRMConfig):
    """dense: (B, dense_features); sparse_ids: (B, n_tables) int32."""
    bot = _mlp(params["bottom"], dense)  # (B, E)
    # Per-table lookup (the Pallas embedding-bag kernel fuses this on TPU).
    emb = jnp.einsum(
        "tbe->bte",
        params["tables"][jnp.arange(cfg.n_tables)[:, None], sparse_ids.T],
    )  # (B, T, E)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, T+1, E)
    inter = jnp.einsum("bte,bse->bts", feats, feats)
    iu, ju = jnp.triu_indices(cfg.n_tables + 1, k=1)
    flat = inter[:, iu, ju]  # (B, n_pairs)
    top_in = jnp.concatenate([bot, flat], axis=1)
    return _mlp(params["top"], top_in)[:, 0]


def loss_fn(params, batch, cfg: DLRMConfig):
    logits = forward(params, batch["dense"], batch["sparse"], cfg)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"bce": loss}
