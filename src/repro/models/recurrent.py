"""Recurrent stacks: RecurrentGemma/Griffin hybrid (RG-LRU + local attention,
pattern 2:1) and Falcon-Mamba (pure Mamba-1 SSM).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..parallel.act_sharding import constrain
from . import layers as L


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Falcon-Mamba (ssm)
# ---------------------------------------------------------------------------


def init_mamba_params(key, cfg: ArchConfig):
    ke, kb, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "embed": L.truncated_normal(ke, (cfg.vocab, cfg.d_model), 0.02, dt),
        "blocks": _stack_init(kb, cfg.n_layers, partial(L.init_mamba, cfg=cfg)),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }


def mamba_forward(params, cfg: ArchConfig, tokens, remat: str = "full",
                  chunk: int | None = None):
    from ..parallel.options import get_options

    chunk = chunk or get_options().scan_chunk
    x = params["embed"][tokens].astype(jnp.dtype(cfg.activation_dtype))

    def block_fn(h, blk):
        h = constrain(h, "btd")
        y, _ = L.mamba_block(blk, L.rms_norm(h, blk["norm"]), cfg, chunk=chunk)
        return constrain(h + y, "btd"), None

    if remat != "none":
        block_fn = jax.checkpoint(block_fn)
    x, _ = lax.scan(block_fn, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, jnp.float32(0.0)


def mamba_prefill(params, cfg: ArchConfig, tokens, chunk: int = 256):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.activation_dtype))

    def block_fn(h, blk):
        h = constrain(h, "btd")
        y, st = L.mamba_block(blk, L.rms_norm(h, blk["norm"]), cfg,
                              state=None, chunk=chunk)
        return constrain(h + y, "btd"), st

    x, states = lax.scan(block_fn, x, params["blocks"])
    x = L.rms_norm(x[:, -1], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
    cache = {"conv": states["conv"], "ssm": states["ssm"]}
    return logits, cache


def mamba_decode_step(params, cfg: ArchConfig, token, pos, cache):
    x = params["embed"][token].astype(jnp.dtype(cfg.activation_dtype))

    def block_fn(h, xs):
        blk, conv, ssm = xs
        y, st = L.mamba_block(
            blk, L.rms_norm(h, blk["norm"])[:, None], cfg,
            state={"conv": conv, "ssm": ssm}, chunk=1,
        )
        return h + y[:, 0], (st["conv"], st["ssm"])

    x, (conv, ssm) = lax.scan(block_fn, x,
                              (params["blocks"], cache["conv"], cache["ssm"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
    return logits, {"conv": conv, "ssm": ssm}


# ---------------------------------------------------------------------------
# RecurrentGemma / Griffin (hybrid)
# ---------------------------------------------------------------------------


def _init_rec_layer(key, cfg):
    kr, km = jax.random.split(key)
    return {"rec": L.init_rglru(kr, cfg), "mlp": L.init_mlp(km, cfg)}


def _init_attn_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {"attn": L.init_attention(ka, cfg), "mlp": L.init_mlp(km, cfg)}


def init_griffin_params(key, cfg: ArchConfig):
    ke, kb, kt, kh = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    n_blocks = cfg.n_layers // len(cfg.block_pattern)

    def init_triple(k):
        k1, k2 = jax.random.split(k)
        return {
            "rec": _stack_init(k1, 2, partial(_init_rec_layer, cfg=cfg)),
            "attn": _init_attn_layer(k2, cfg),
        }

    params = {
        "embed": L.truncated_normal(ke, (cfg.vocab, cfg.d_model), 0.02, dt),
        "blocks": _stack_init(kb, n_blocks, init_triple),
        "tail": _stack_init(kt, len(cfg.tail_pattern),
                            partial(_init_rec_layer, cfg=cfg)),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }
    return params


def _rec_layer_apply(lyr, h, cfg, state=None, chunk=256):
    y, st = L.rglru_block(lyr["rec"], L.rms_norm(h, lyr["rec"]["norm"]), cfg,
                          state=state, chunk=chunk)
    h = h + y
    h = h + L.mlp(lyr["mlp"], L.rms_norm(h, lyr["mlp"]["norm"]))
    return h, st


def _attn_layer_apply(lyr, h, cfg, mask, positions):
    h = h + L.attention(lyr["attn"], L.rms_norm(h, lyr["attn"]["norm"]), cfg,
                        mask=mask, causal=True, window=cfg.attn_window,
                        positions=positions)
    h = h + L.mlp(lyr["mlp"], L.rms_norm(h, lyr["mlp"]["norm"]))
    return h


def griffin_forward(params, cfg: ArchConfig, tokens, remat: str = "full",
                    chunk: int = 256):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.activation_dtype))
    S = tokens.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = None

    def rec_fn(h, lyr):
        h, _ = _rec_layer_apply(lyr, constrain(h, "btd"), cfg, chunk=chunk)
        return constrain(h, "btd"), None

    def triple_fn(h, blk):
        h, _ = lax.scan(rec_fn, h, blk["rec"])
        h = _attn_layer_apply(blk["attn"], h, cfg, mask, positions)
        return constrain(h, "btd"), None

    if remat != "none":
        triple_fn = jax.checkpoint(triple_fn)
    x, _ = lax.scan(triple_fn, x, params["blocks"])
    x, _ = lax.scan(rec_fn, x, params["tail"])
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, jnp.float32(0.0)


def griffin_prefill(params, cfg: ArchConfig, tokens, chunk: int = 256):
    act = jnp.dtype(cfg.activation_dtype)
    x = params["embed"][tokens].astype(act)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    mask = None
    window = min(cfg.attn_window, S)
    hd = cfg.hd

    def rec_fn(h, lyr):
        h, st = _rec_layer_apply(lyr, h, cfg, chunk=chunk)
        return h, st

    def triple_fn(h, blk):
        h, rec_states = lax.scan(rec_fn, h, blk["rec"])
        lyr = blk["attn"]
        src = L.rms_norm(h, lyr["attn"]["norm"])
        k = L._split_heads(jnp.einsum("btd,de->bte", src, lyr["attn"]["wk"]),
                           cfg.n_kv_heads, hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        v = L._split_heads(jnp.einsum("btd,de->bte", src, lyr["attn"]["wv"]),
                           cfg.n_kv_heads, hd)
        # Keep the last `window` positions, laid out as a ring buffer
        # (slot = pos % window) so decode can continue in place.
        kw = k[:, -window:].transpose(0, 2, 1, 3)
        vw = v[:, -window:].transpose(0, 2, 1, 3)
        start = S - window
        roll = -(start % window)
        kw = jnp.roll(kw, roll, axis=2)
        vw = jnp.roll(vw, roll, axis=2)
        h = _attn_layer_apply(lyr, h, cfg, mask, positions)
        return h, (rec_states, (kw.astype(act), vw.astype(act)))

    x, (rec_states, kv) = lax.scan(triple_fn, x, params["blocks"])
    x, tail_states = lax.scan(rec_fn, x, params["tail"])
    x = L.rms_norm(x[:, -1], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])

    # rec_states: dict of (n_blocks, 2, ...) -> flatten; tail: (2, ...)
    def flat(main, tail):
        m = main.reshape(-1, *main.shape[2:])
        return jnp.concatenate([m, tail], axis=0)

    cache = {
        "lru": flat(rec_states["lru"], tail_states["lru"]),
        "conv": flat(rec_states["conv"], tail_states["conv"]),
        "k": kv[0],
        "v": kv[1],
    }
    return logits, cache


def griffin_decode_step(params, cfg: ArchConfig, token, pos, cache):
    x = params["embed"][token].astype(jnp.dtype(cfg.activation_dtype))
    n_blocks = cfg.n_layers // len(cfg.block_pattern)
    n_rec_main = n_blocks * 2

    lru_m = cache["lru"][:n_rec_main].reshape(n_blocks, 2, *cache["lru"].shape[1:])
    conv_m = cache["conv"][:n_rec_main].reshape(n_blocks, 2, *cache["conv"].shape[1:])
    lru_t, conv_t = cache["lru"][n_rec_main:], cache["conv"][n_rec_main:]

    def rec_fn(h, xs):
        lyr, lru, conv = xs
        h2, st = _rec_layer_apply(
            lyr, h[:, None], cfg, state={"lru": lru, "conv": conv}, chunk=1
        )
        return h2[:, 0], (st["lru"], st["conv"])

    def rec_fn_seq(h, xs):
        # same but h stays (B, D): wrap/unwrap inside
        return rec_fn(h, xs)

    def triple_fn(h, xs):
        blk, lru, conv, ck, cv = xs
        h, rec_st = lax.scan(rec_fn_seq, h, (blk["rec"], lru, conv))
        lyr = blk["attn"]
        att, nk, nv = L.attention_decode(
            lyr["attn"], L.rms_norm(h, lyr["attn"]["norm"]), ck, cv, pos, cfg,
            window=cfg.attn_window,
        )
        h = h + att
        h = h + L.mlp(lyr["mlp"], L.rms_norm(h, lyr["mlp"]["norm"]))
        return h, (rec_st, (nk, nv))

    x, (rec_st, kv) = lax.scan(
        triple_fn, x, (params["blocks"], lru_m, conv_m, cache["k"], cache["v"])
    )
    x, tail_st = lax.scan(rec_fn_seq, x, (params["tail"], lru_t, conv_t))
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])

    def flat(main, tail):
        m = main.reshape(-1, *main.shape[2:])
        return jnp.concatenate([m, tail], axis=0)

    new_cache = {
        "lru": flat(rec_st[0], tail_st[0]),
        "conv": flat(rec_st[1], tail_st[1]),
        "k": kv[0],
        "v": kv[1],
    }
    return logits, new_cache
