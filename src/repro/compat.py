"""Version-compatibility shims for the jax surface the repo touches.

jax moved ``shard_map`` out of ``jax.experimental`` (and renamed its
replication-check kwarg ``check_rep`` -> ``check_vma``) across 0.4.x -> 0.5+.
``shard_map_compat`` papers over both so callers write one code path.

:func:`ensure_x64` pins 64-bit JAX arithmetic for the planner backend
(:mod:`repro.core.planeval_jax`): the NumPy plan evaluator is float64, and
CPU CI must price candidates at the same precision on every run or the
JAX-vs-NumPy equivalence tolerances drift with the platform default.
"""

from __future__ import annotations

import inspect
import os
from functools import lru_cache

# Truthiness table for JAX_ENABLE_X64-style env switches.
_FALSY = {"0", "false", "False", "FALSE", ""}


def ensure_x64(enable: bool | None = None) -> bool:
    """Enable (or explicitly pin) 64-bit JAX arithmetic, idempotently.

    ``enable=None`` honours an existing ``JAX_ENABLE_X64`` environment
    setting and defaults to *on* when unset — the deterministic-CI posture:
    the planner's JAX backend always prices candidates in float64, matching
    the NumPy reference, unless the environment explicitly opts out.
    Returns the effective x64 state.  Safe to call repeatedly, before or
    after other jax use (``jax.config.update`` is retroactive for newly
    minted arrays; the planner builds all of its arrays after this call).
    """
    import jax

    if enable is None:
        env = os.environ.get("JAX_ENABLE_X64")
        enable = True if env is None else env not in _FALSY
    jax.config.update("jax_enable_x64", bool(enable))
    return bool(jax.config.jax_enable_x64)


@lru_cache(maxsize=1)
def _resolve_shard_map():
    try:
        from jax import shard_map as sm  # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm  # jax 0.4.x/0.5.x
    params = inspect.signature(sm).parameters
    if "check_vma" in params:
        check_kwarg = "check_vma"
    elif "check_rep" in params:
        check_kwarg = "check_rep"
    else:
        check_kwarg = None
    return sm, check_kwarg


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_replication=False):
    """``shard_map`` with the replication check toggled portably."""
    sm, check_kwarg = _resolve_shard_map()
    kwargs = {check_kwarg: check_replication} if check_kwarg else {}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis (``lax.axis_size`` only exists on
    newer jax; ``psum(1, axis)`` is the portable spelling and stays static)."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)
